//! Minimal JSON reader/writer (the offline crate set has no serde).
//!
//! Supports the subset needed by calibration files, the artifact
//! manifest, and experiment outputs: objects, arrays, strings, f64
//! numbers, booleans, null. Numbers are emitted with enough digits to
//! round-trip f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::num_exact(x)).collect())
    }

    /// Encode an f64 exactly, including non-finite values: the minimal
    /// JSON grammar has no `inf`/`nan` literal, so those travel as the
    /// strings `"inf"`, `"-inf"`, `"nan"` (plain `Json::Num` would emit
    /// an unparseable bare token for them).
    pub fn num_exact(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else if x == f64::INFINITY {
            Json::Str("inf".into())
        } else if x == f64::NEG_INFINITY {
            Json::Str("-inf".into())
        } else {
            Json::Str("nan".into())
        }
    }

    /// Decode an f64 written by [`Json::num_exact`].
    pub fn as_f64_exact(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Str(s) if s == "inf" => Some(f64::INFINITY),
            Json::Str(s) if s == "-inf" => Some(f64::NEG_INFINITY),
            Json::Str(s) if s == "nan" => Some(f64::NAN),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Non-negative whole-number extraction (counts, sizes). Fails on
    /// fractional values and on values too large for f64 to represent
    /// exactly (>= 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        let x = self.as_f64()?;
        if x >= 0.0 && x.fract() == 0.0 && x < 9_007_199_254_740_992.0 {
            Some(x as usize)
        } else {
            None
        }
    }

    /// Encode a full-width `u64` (seeds, fingerprints). These do not
    /// survive the f64 `Num` representation above 2^53, so they travel
    /// as decimal strings.
    pub fn u64_str(v: u64) -> Json {
        Json::Str(v.to_string())
    }

    /// Decode a `u64` written by [`Json::u64_str`] (a small integral
    /// `Num` is accepted too, for hand-written files).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Str(s) => s.parse().ok(),
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9_007_199_254_740_992.0 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// `[f64]` extraction helper (accepts the [`Json::num_exact`]
    /// string encoding of non-finite values).
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64_exact()).collect()
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // -0.0 must keep its sign bit (`as i64` would drop it and
                // break bit-exact f64 round-trips, e.g. fingerprints over
                // serialized model coefficients); `{:e}` emits "-0e0".
                if x.fract() == 0.0 && x.abs() < 1e15 && !(*x == 0.0 && x.is_sign_negative())
                {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{:e}", x);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy the full UTF-8 character.
                    let text = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::Str("dahu".into())),
            ("nodes", Json::Num(32.0)),
            ("alpha", Json::arr_f64(&[1.05e-11, 2.0, -3.5])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let v = Json::parse(" { \"a\" : [ 1 , 2.5e-3 ] , \"s\" : \"x\\ny\\\"\" } ").unwrap();
        assert_eq!(v.get("a").unwrap().f64_vec().unwrap(), vec![1.0, 0.0025]);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x\ny\"");
    }

    #[test]
    fn f64_roundtrip_precision() {
        let xs = [1.0293e-11, std::f64::consts::PI, -1.0 / 3.0, 1e300];
        let s = Json::arr_f64(&xs).to_string();
        let back = Json::parse(&s).unwrap().f64_vec().unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a, b, "{a} vs {b}");
        }
    }

    #[test]
    fn non_finite_f64s_roundtrip_via_num_exact() {
        for x in [f64::INFINITY, f64::NEG_INFINITY, 1.5, -2e300, 0.0] {
            let s = Json::num_exact(x).to_string();
            let back = Json::parse(&s).unwrap().as_f64_exact().unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x} mangled (wrote {s})");
        }
        let s = Json::num_exact(f64::NAN).to_string();
        assert!(Json::parse(&s).unwrap().as_f64_exact().unwrap().is_nan());
        // Arrays (model coefficients, link capacities) go through the
        // same encoding.
        let xs = [1.0, f64::INFINITY, -3.5];
        let back = Json::parse(&Json::arr_f64(&xs).to_string()).unwrap().f64_vec().unwrap();
        assert_eq!(back, xs);
    }

    #[test]
    fn negative_zero_roundtrips_bit_exactly() {
        let s = Json::Num(-0.0).to_string();
        let back = Json::parse(&s).unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits(), "sign of -0.0 lost ({s})");
        // Positive zero still takes the compact integer path.
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn u64_full_width_roundtrip() {
        // Full-width values (e.g. derived seeds, fingerprints) would be
        // mangled by the f64 Num path; the string encoding is exact.
        for v in [0u64, 1, u64::MAX, 0xdead_beef_cafe_f00d] {
            let s = Json::u64_str(v).to_string();
            assert_eq!(Json::parse(&s).unwrap().as_u64(), Some(v));
        }
        // Small integral Nums are accepted for convenience.
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(0.5).as_u64(), None);
        assert_eq!(Json::Num(1e17).as_u64(), None);
    }

    #[test]
    fn usize_extraction_checks_integrality() {
        assert_eq!(Json::Num(128.0).as_usize(), Some(128));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-2.0).as_usize(), None);
        assert_eq!(Json::Num(2.5).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"feats": 8, "dgemm_model_512": {"inputs": [{"shape": [512, 4], "dtype": "float32"}], "outputs": [{"shape": [512], "dtype": "float32"}]}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("feats").unwrap().as_f64().unwrap(), 8.0);
        let ins = v
            .get("dgemm_model_512")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(ins[0].get("shape").unwrap().f64_vec().unwrap(), vec![512.0, 4.0]);
    }
}
