//! Variance-based global sensitivity analysis: sample-plan generators
//! (Latin hypercube, Saltelli) and Sobol index estimators.
//!
//! All plans live on the unit hypercube `[0,1)^d`; the
//! design-of-experiments layer (`coordinator::doe`) owns the mapping
//! from unit coordinates to concrete `(HplConfig, PlatformScenario)`
//! points. Keeping the generators dimension-agnostic here means the
//! estimator can be validated against analytic test functions
//! (Ishigami) with no simulator in the loop.
//!
//! Estimators are the Saltelli-2010 first-order form and the Jansen
//! total-order form, the same pairing the UQ literature (and the
//! SALib/UQ_PhysiCell harnesses this reproduces) default to:
//!
//! ```text
//! S_i  = mean_j( f(B)_j * (f(AB_i)_j - f(A)_j) ) / V
//! ST_i = mean_j( (f(A)_j - f(AB_i)_j)^2 ) / (2 V)
//! ```
//!
//! with `V` the variance of the pooled `f(A) ∪ f(B)` sample.

use super::rng::Rng;

/// Latin hypercube sample: `n` points in `[0,1)^dims`, each dimension
/// stratified into `n` equal strata with exactly one point per stratum,
/// strata paired across dimensions by independent random permutations.
pub fn lhs(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f64>> {
    assert!(n > 0 && dims > 0, "lhs needs n > 0 and dims > 0");
    let mut out = vec![vec![0.0; dims]; n];
    let mut strata: Vec<usize> = (0..n).collect();
    for d in 0..dims {
        for (i, s) in strata.iter_mut().enumerate() {
            *s = i;
        }
        rng.shuffle(&mut strata);
        for (row, &s) in out.iter_mut().zip(strata.iter()) {
            row[d] = (s as f64 + rng.uniform()) / n as f64;
        }
    }
    out
}

/// Number of rows a Saltelli plan of base size `n_base` over `dims`
/// dimensions contains: the A and B matrices plus one AB_i matrix per
/// dimension.
pub fn saltelli_len(n_base: usize, dims: usize) -> usize {
    n_base * (dims + 2)
}

/// Saltelli sample plan: two independent uniform matrices `A` and `B`
/// (`n_base` rows each) followed by the `dims` hybrid matrices `AB_i`
/// (`A` with column `i` replaced by `B`'s column `i`), concatenated in
/// the fixed order `[A; B; AB_0; ...; AB_{d-1}]` that
/// [`sobol_indices`] expects.
///
/// The layout is what makes campaign-level dedup free downstream: every
/// `AB_i` row shares `d-1` coordinates with an `A` row, so coarse
/// (categorical / low-level-count) dimensions frequently map `AB_i`
/// rows onto configurations the campaign already fingerprinted.
pub fn saltelli(rng: &mut Rng, n_base: usize, dims: usize) -> Vec<Vec<f64>> {
    assert!(n_base > 0 && dims > 0, "saltelli needs n_base > 0 and dims > 0");
    let a: Vec<Vec<f64>> =
        (0..n_base).map(|_| (0..dims).map(|_| rng.uniform()).collect()).collect();
    let b: Vec<Vec<f64>> =
        (0..n_base).map(|_| (0..dims).map(|_| rng.uniform()).collect()).collect();
    let mut rows = Vec::with_capacity(saltelli_len(n_base, dims));
    rows.extend(a.iter().cloned());
    rows.extend(b.iter().cloned());
    for i in 0..dims {
        for (ra, rb) in a.iter().zip(&b) {
            let mut h = ra.clone();
            h[i] = rb[i];
            rows.push(h);
        }
    }
    rows
}

/// First-order and total-order Sobol indices.
#[derive(Clone, Debug)]
pub struct SobolIndices {
    /// First-order index per dimension (Saltelli 2010 estimator).
    pub s1: Vec<f64>,
    /// Total-order index per dimension (Jansen estimator).
    pub st: Vec<f64>,
    /// Mean of the pooled `f(A) ∪ f(B)` sample.
    pub mean: f64,
    /// Variance of the pooled `f(A) ∪ f(B)` sample.
    pub variance: f64,
}

/// Estimate Sobol indices from responses `y` evaluated on a
/// [`saltelli`] plan of base size `n_base` over `dims` dimensions, in
/// plan order. A degenerate (zero-variance) response — e.g. the
/// plan-only placeholder results — yields all-zero indices rather than
/// NaNs.
pub fn sobol_indices(y: &[f64], n_base: usize, dims: usize) -> SobolIndices {
    assert_eq!(
        y.len(),
        saltelli_len(n_base, dims),
        "response length must match the Saltelli plan"
    );
    let f_a = &y[..n_base];
    let f_b = &y[n_base..2 * n_base];

    let pooled = 2 * n_base;
    let mean = (f_a.iter().sum::<f64>() + f_b.iter().sum::<f64>()) / pooled as f64;
    let variance = (f_a.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
        + f_b.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>())
        / pooled as f64;

    let mut s1 = vec![0.0; dims];
    let mut st = vec![0.0; dims];
    if variance > 0.0 {
        for i in 0..dims {
            let f_abi = &y[(2 + i) * n_base..(3 + i) * n_base];
            let mut first = 0.0;
            let mut total = 0.0;
            for j in 0..n_base {
                first += f_b[j] * (f_abi[j] - f_a[j]);
                let d = f_a[j] - f_abi[j];
                total += d * d;
            }
            s1[i] = first / n_base as f64 / variance;
            st[i] = total / (2.0 * n_base as f64) / variance;
        }
    }
    SobolIndices { s1, st, mean, variance }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lhs_is_stratified_per_dimension() {
        let mut rng = Rng::new(11);
        let n = 16;
        let dims = 3;
        let pts = lhs(&mut rng, n, dims);
        assert_eq!(pts.len(), n);
        for d in 0..dims {
            let mut seen = vec![false; n];
            for row in &pts {
                assert!(row[d] >= 0.0 && row[d] < 1.0, "out of unit cube: {}", row[d]);
                let stratum = (row[d] * n as f64) as usize;
                assert!(!seen[stratum], "dimension {d} stratum {stratum} hit twice");
                seen[stratum] = true;
            }
            assert!(seen.iter().all(|&s| s), "dimension {d} missed a stratum");
        }
    }

    #[test]
    fn lhs_is_deterministic_per_seed() {
        let a = lhs(&mut Rng::new(5), 8, 2);
        let b = lhs(&mut Rng::new(5), 8, 2);
        assert_eq!(a, b);
        let c = lhs(&mut Rng::new(6), 8, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn saltelli_layout_and_hybrid_rows() {
        let n = 4;
        let dims = 3;
        let rows = saltelli(&mut Rng::new(3), n, dims);
        assert_eq!(rows.len(), saltelli_len(n, dims));
        let a = &rows[..n];
        let b = &rows[n..2 * n];
        for i in 0..dims {
            let abi = &rows[(2 + i) * n..(3 + i) * n];
            for j in 0..n {
                for d in 0..dims {
                    let want = if d == i { b[j][d] } else { a[j][d] };
                    assert_eq!(abi[j][d], want, "AB_{i} row {j} dim {d}");
                }
            }
        }
    }

    /// Ishigami function: the standard analytic benchmark for Sobol
    /// estimators. With `a = 7`, `b = 0.1` on `x ∈ [-π, π]^3` the
    /// closed-form indices are
    /// `S1 ≈ 0.3139, S2 ≈ 0.4424, S3 = 0`,
    /// `ST1 ≈ 0.5576, ST2 ≈ 0.4424, ST3 ≈ 0.2437`.
    #[test]
    fn ishigami_closed_form_within_tolerance() {
        use std::f64::consts::PI;
        let (a, b) = (7.0, 0.1);
        let n_base = 16384;
        let dims = 3;
        let plan = saltelli(&mut Rng::new(20260807), n_base, dims);
        let y: Vec<f64> = plan
            .iter()
            .map(|u| {
                let x: Vec<f64> = u.iter().map(|&v| -PI + 2.0 * PI * v).collect();
                x[0].sin() + a * x[1].sin().powi(2) + b * x[2].powi(4) * x[0].sin()
            })
            .collect();
        let ix = sobol_indices(&y, n_base, dims);

        // Closed form: V1 = (1 + b π^4 / 5)^2 / 2, V2 = a^2 / 8,
        // V13 = 8 b^2 π^8 / 225, D = V1 + V2 + V13.
        let v1 = 0.5 * (1.0 + b * PI.powi(4) / 5.0).powi(2);
        let v2 = a * a / 8.0;
        let v13 = 8.0 * b * b * PI.powi(8) / 225.0;
        let d = v1 + v2 + v13;
        let want_s1 = [v1 / d, v2 / d, 0.0];
        let want_st = [(v1 + v13) / d, v2 / d, v13 / d];

        let tol = 0.03;
        assert!((ix.variance - d).abs() < 0.05 * d, "variance {} want {d}", ix.variance);
        for i in 0..dims {
            assert!(
                (ix.s1[i] - want_s1[i]).abs() < tol,
                "S{}: {} want {}",
                i + 1,
                ix.s1[i],
                want_s1[i]
            );
            assert!(
                (ix.st[i] - want_st[i]).abs() < tol,
                "ST{}: {} want {}",
                i + 1,
                ix.st[i],
                want_st[i]
            );
        }
    }

    #[test]
    fn degenerate_response_yields_zero_indices() {
        let n_base = 8;
        let dims = 2;
        let y = vec![3.5; saltelli_len(n_base, dims)];
        let ix = sobol_indices(&y, n_base, dims);
        assert_eq!(ix.variance, 0.0);
        assert!(ix.s1.iter().chain(&ix.st).all(|&v| v == 0.0));
    }

    /// Additive linear function: S_i known exactly, ST_i == S_i.
    #[test]
    fn additive_function_first_equals_total() {
        let n_base = 8192;
        let dims = 3;
        let w = [3.0, 2.0, 1.0];
        let plan = saltelli(&mut Rng::new(99), n_base, dims);
        let y: Vec<f64> = plan
            .iter()
            .map(|u| u.iter().zip(&w).map(|(v, c)| c * v).sum())
            .collect();
        let ix = sobol_indices(&y, n_base, dims);
        // V_i = w_i^2 / 12 for uniform inputs on [0,1).
        let d: f64 = w.iter().map(|c| c * c / 12.0).sum();
        for i in 0..dims {
            let want = w[i] * w[i] / 12.0 / d;
            assert!((ix.s1[i] - want).abs() < 0.02, "S{i}: {} want {want}", ix.s1[i]);
            assert!((ix.st[i] - want).abs() < 0.02, "ST{i}: {} want {want}", ix.st[i]);
        }
    }
}
