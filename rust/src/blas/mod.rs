//! Statistical compute-kernel models (the paper's Eq. 1/2) and the
//! duration sources that feed them into the simulation.
//!
//! The dgemm model is the performance-critical one: per node `p`,
//!
//! ```text
//! dgemm_p(M, N, K) ~ H(mu_p, sigma_p)
//! mu_p    = a_p MNK + b_p MN + c_p MK + d_p NK + e_p
//! sigma_p = w_p MNK + x_p MN + y_p MK + z_p NK + r_p
//! ```
//!
//! with `H` half-normal. In production runs, durations are evaluated in
//! large batches through the AOT-compiled XLA artifact (see
//! [`provider::PoolSource`] and `runtime`); a pure-Rust path exists for
//! tests and cross-checks.
//!
//! The remaining kernels (dtrsm, dger, dlatcpy, daxpy, idamax) follow
//! the paper's simple deterministic linear models.

pub mod model;
pub mod provider;

pub use model::{DgemmModel, LinearModel, NodeCoef, N_COEF};
pub use provider::{
    DgemmSource, DirectSource, PoolSource, RecordedCalls, Recorder, ReplayError,
};

use std::rc::Rc;

/// The full kernel-model set used by one simulation.
#[derive(Clone)]
pub struct KernelModels {
    /// dgemm duration source (stochastic polynomial, possibly pooled).
    pub dgemm: Rc<dyn DgemmSource>,
    /// dtrsm(jb, n): triangular solve of a jb x jb block against n columns;
    /// linear in `jb*jb*n`.
    pub dtrsm: LinearModel,
    /// dger / rank-1 update, linear in `m*n`.
    pub dger: LinearModel,
    /// dlatcpy (panel copy), linear in `m*n`.
    pub dlatcpy: LinearModel,
    /// daxpy, linear in `n`.
    pub daxpy: LinearModel,
    /// idamax, linear in `n`.
    pub idamax: LinearModel,
}

impl KernelModels {
    /// Deterministic defaults matching a ~2017 Xeon (used by tests and
    /// as the non-dgemm part of every platform: the paper models these
    /// kernels homogeneously and deterministically).
    pub fn default_aux(dgemm: Rc<dyn DgemmSource>) -> KernelModels {
        KernelModels {
            dgemm,
            // ~25 GF/s effective on the small triangular solves.
            dtrsm: LinearModel { slope: 8.0e-11, intercept: 2.0e-7 },
            dger: LinearModel { slope: 2.5e-10, intercept: 2.0e-7 },
            dlatcpy: LinearModel { slope: 1.0e-10, intercept: 1.5e-7 },
            daxpy: LinearModel { slope: 2.0e-10, intercept: 1.0e-7 },
            idamax: LinearModel { slope: 1.5e-10, intercept: 1.0e-7 },
        }
    }
}
