//! Duration sources for the dgemm model.
//!
//! HPL's control flow is data-independent: the exact sequence of dgemm
//! shapes issued by each rank is a pure function of the configuration.
//! Production simulations therefore run **two passes**:
//!
//! 1. a *recording* pass with [`Recorder`] (cheap mean-only durations)
//!    that captures every `(m, n, k)` per rank in program order,
//! 2. a batched evaluation of all durations through the XLA artifact
//!    (`runtime::Artifacts::dgemm_durations`) producing per-rank pools,
//! 3. a *replay* pass with [`PoolSource`] that pops pooled durations in
//!    the same program order (shapes are asserted to match).
//!
//! [`DirectSource`] samples in pure Rust — used by unit tests and as a
//! cross-check of the artifact path.

use std::cell::RefCell;
use std::rc::Rc;

use super::model::DgemmModel;
use crate::stats::Rng;

/// Anything that can produce the duration of the next dgemm call of a
/// given rank.
///
/// `epoch` identifies the HPL iteration issuing the call: the half-normal
/// noise is drawn **once per (rank, epoch)** — temporal variability is
/// episodic (OS noise, frequency excursions), so every kernel of an
/// iteration is slowed by the same factor instead of averaging out over
/// the per-NB update chunks. This is also what lets the noise propagate
/// through the communication pattern (late sends), the paper's §3.4
/// observation.
pub trait DgemmSource {
    /// Duration (seconds) of the next dgemm `(m, n, k)` issued by
    /// `rank`, which runs on `node`, during iteration `epoch`.
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64;
}

/// The per-(rank, epoch) standard-normal draw shared by every kernel of
/// that rank's iteration. Counter-based: reproducible and random-access.
pub fn epoch_z(seed: u64, rank: usize, epoch: usize) -> f64 {
    Rng::new(seed).derive(rank as u64).derive(epoch as u64).normal()
}

/// Pure-Rust sampling straight from the model.
pub struct DirectSource {
    model: DgemmModel,
    seed: u64,
    stochastic: bool,
}

impl DirectSource {
    pub fn new(model: DgemmModel, _nranks: usize, seed: u64) -> Rc<Self> {
        Rc::new(DirectSource { model, seed, stochastic: true })
    }

    /// Mean-only variant (deterministic).
    pub fn deterministic(model: DgemmModel, _nranks: usize) -> Rc<Self> {
        Rc::new(DirectSource { model, seed: 0, stochastic: false })
    }
}

impl DgemmSource for DirectSource {
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64 {
        if self.stochastic {
            let z = epoch_z(self.seed, rank, epoch).abs();
            let c = self.model.coef(node);
            let (mf, nf, kf) = (m as f64, n as f64, k as f64);
            (c.mu_of(mf, nf, kf) + z * c.sigma_of(mf, nf, kf)).max(0.0)
        } else {
            self.model.mu(node, m, n, k)
        }
    }
}

/// Recording pass: returns cheap mean durations and logs every shape.
pub struct Recorder {
    model: DgemmModel,
    /// Per rank: `(node, epoch, m, n, k)` in program order.
    pub calls: RefCell<Vec<Vec<(u32, u32, u32, u32, u32)>>>,
}

impl Recorder {
    pub fn new(model: DgemmModel, nranks: usize) -> Rc<Self> {
        Rc::new(Recorder {
            model,
            calls: RefCell::new(vec![Vec::new(); nranks]),
        })
    }

    /// Total recorded calls.
    pub fn total(&self) -> usize {
        self.calls.borrow().iter().map(|v| v.len()).sum()
    }

    /// Flatten to the artifact's batched layout:
    /// `(mnk, node_idx, per-call (rank, epoch))`.
    pub fn flatten(&self) -> (Vec<[f32; 3]>, Vec<i32>, Vec<(u32, u32)>) {
        let calls = self.calls.borrow();
        let mut mnk = Vec::with_capacity(self.total());
        let mut idx = Vec::with_capacity(self.total());
        let mut rank_epoch = Vec::with_capacity(self.total());
        for (rank, per_rank) in calls.iter().enumerate() {
            for &(node, epoch, m, n, k) in per_rank {
                mnk.push([m as f32, n as f32, k as f32]);
                idx.push(node as i32);
                rank_epoch.push((rank as u32, epoch));
            }
        }
        (mnk, idx, rank_epoch)
    }
}

impl DgemmSource for Recorder {
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64 {
        self.calls.borrow_mut()[rank]
            .push((node as u32, epoch as u32, m as u32, n as u32, k as u32));
        self.model.mu(node, m, n, k)
    }
}

/// Replay mismatch diagnostics.
#[derive(Clone, Debug)]
pub struct ReplayError {
    pub rank: usize,
    pub call_index: usize,
}

/// Replay pass: pops pre-evaluated durations per rank in program order.
pub struct PoolSource {
    /// Per rank: durations + the shapes they were evaluated for.
    durations: RefCell<Vec<std::iter::Peekable<std::vec::IntoIter<f64>>>>,
    shapes: Vec<Vec<(u32, u32, u32, u32, u32)>>,
    cursor: RefCell<Vec<usize>>,
    /// Check shapes on every pop (cheap; always on).
    verify: bool,
}

impl PoolSource {
    /// `durations` flattened in the same order as `Recorder::flatten`.
    pub fn new(
        recorder: &Recorder,
        flat_durations: &[f32],
    ) -> Rc<Self> {
        let calls = recorder.calls.borrow();
        let mut per_rank = Vec::with_capacity(calls.len());
        let mut off = 0usize;
        for rank_calls in calls.iter() {
            let n = rank_calls.len();
            let durs: Vec<f64> =
                flat_durations[off..off + n].iter().map(|&d| d as f64).collect();
            per_rank.push(durs.into_iter().peekable());
            off += n;
        }
        assert_eq!(off, flat_durations.len(), "pool size mismatch");
        Rc::new(PoolSource {
            durations: RefCell::new(per_rank),
            shapes: calls.clone(),
            cursor: RefCell::new(vec![0; calls.len()]),
            verify: true,
        })
    }
}

impl DgemmSource for PoolSource {
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64 {
        if self.verify {
            let mut cur = self.cursor.borrow_mut();
            let i = cur[rank];
            let expect = self.shapes[rank].get(i).copied().unwrap_or_else(|| {
                panic!("rank {rank}: replay ran past recorded schedule at call {i}")
            });
            assert_eq!(
                expect,
                (node as u32, epoch as u32, m as u32, n as u32, k as u32),
                "rank {rank} call {i}: replay shape diverged from recording"
            );
            cur[rank] = i + 1;
        }
        self.durations.borrow_mut()[rank]
            .next()
            .expect("duration pool exhausted")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::model::NodeCoef;

    fn model() -> DgemmModel {
        DgemmModel {
            nodes: vec![
                NodeCoef {
                    mu: [1e-11, 0.0, 0.0, 0.0, 1e-6],
                    sigma: [3e-13, 0.0, 0.0, 0.0, 0.0],
                },
                NodeCoef {
                    mu: [2e-11, 0.0, 0.0, 0.0, 1e-6],
                    sigma: [0.0; 5],
                },
            ],
        }
    }

    #[test]
    fn direct_streams_are_independent_per_rank_and_epoch() {
        let s = DirectSource::new(model(), 2, 42);
        let a = s.next(0, 0, 0, 100, 100, 100);
        let b = s.next(1, 0, 0, 100, 100, 100);
        assert_ne!(a, b);
        // Same (rank, epoch) -> same noise draw (episodic model).
        assert_eq!(a, s.next(0, 0, 0, 100, 100, 100));
        // Different epoch -> different draw.
        assert_ne!(a, s.next(0, 0, 1, 100, 100, 100));
        // Re-creating with the same seed replays identically.
        let s2 = DirectSource::new(model(), 2, 42);
        assert_eq!(s2.next(0, 0, 0, 100, 100, 100), a);
    }

    #[test]
    fn epoch_noise_scales_whole_iteration() {
        // With sigma proportional to mu, two calls of one epoch see the
        // same slowdown factor: d1/mu1 == d2/mu2.
        let m = model();
        let s = DirectSource::new(m.clone(), 1, 7);
        let d1 = s.next(0, 0, 3, 1000, 64, 64);
        let d2 = s.next(0, 0, 3, 2000, 64, 64);
        let r1 = d1 / m.mu(0, 1000, 64, 64);
        let r2 = d2 / m.mu(0, 2000, 64, 64);
        // mu has an intercept so ratios are close, not identical.
        assert!((r1 - r2).abs() < 0.02, "{r1} vs {r2}");
    }

    #[test]
    fn recorder_captures_program_order() {
        let r = Recorder::new(model(), 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(1, 1, 0, 5, 5, 5);
        r.next(0, 0, 1, 11, 21, 31);
        let (mnk, idx, rank_epoch) = r.flatten();
        assert_eq!(mnk[0], [10.0, 20.0, 30.0]);
        assert_eq!(mnk[1], [11.0, 21.0, 31.0]);
        assert_eq!(mnk[2], [5.0, 5.0, 5.0]);
        assert_eq!(idx, vec![0, 0, 1]);
        assert_eq!(rank_epoch, vec![(0, 0), (0, 1), (1, 0)]);
        assert_eq!(r.total(), 3);
    }

    #[test]
    fn pool_replays_in_order_and_verifies_shapes() {
        let r = Recorder::new(model(), 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(0, 0, 1, 11, 21, 31);
        r.next(1, 1, 0, 5, 5, 5);
        let pool = PoolSource::new(&r, &[1.0, 2.0, 3.0]);
        assert_eq!(pool.next(0, 0, 0, 10, 20, 30), 1.0);
        assert_eq!(pool.next(1, 1, 0, 5, 5, 5), 3.0);
        assert_eq!(pool.next(0, 0, 1, 11, 21, 31), 2.0);
    }

    #[test]
    #[should_panic(expected = "diverged")]
    fn pool_panics_on_shape_divergence() {
        let r = Recorder::new(model(), 1);
        r.next(0, 0, 0, 10, 20, 30);
        let pool = PoolSource::new(&r, &[1.0]);
        pool.next(0, 0, 0, 99, 20, 30);
    }
}
