//! Duration sources for the dgemm model.
//!
//! HPL's control flow is data-independent: the exact sequence of dgemm
//! shapes issued by each rank is a pure function of the configuration.
//! Production simulations therefore run **two passes**:
//!
//! 1. a *recording* pass with [`Recorder`] (cheap mean-only durations)
//!    that captures every `(m, n, k)` per rank in program order — and
//!    flattens into a [`runtime::DgemmRequest`](crate::runtime::DgemmRequest)
//!    via [`Recorder::request`],
//! 2. a batched evaluation of all durations through the XLA artifact
//!    (`runtime::Artifacts::evaluate_batch`) producing per-rank pools —
//!    campaigns concatenate *many points'* requests into each
//!    invocation (see `coordinator::backend::artifact`),
//! 3. a *replay* pass with [`PoolSource`] that pops pooled durations in
//!    the same program order (every pop is verified against the
//!    recording; a divergence is a structured [`ReplayError`]).
//!
//! [`DirectSource`] samples in pure Rust — used by unit tests and as a
//! cross-check of the artifact path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::model::DgemmModel;
use crate::runtime::DgemmRequest;
use crate::stats::Rng;

/// Anything that can produce the duration of the next dgemm call of a
/// given rank.
///
/// `epoch` identifies the HPL iteration issuing the call: the half-normal
/// noise is drawn **once per (rank, epoch)** — temporal variability is
/// episodic (OS noise, frequency excursions), so every kernel of an
/// iteration is slowed by the same factor instead of averaging out over
/// the per-NB update chunks. This is also what lets the noise propagate
/// through the communication pattern (late sends), the paper's §3.4
/// observation.
pub trait DgemmSource {
    /// Duration (seconds) of the next dgemm `(m, n, k)` issued by
    /// `rank`, which runs on `node`, during iteration `epoch`.
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64;
}

/// The per-(rank, epoch) standard-normal draw shared by every kernel of
/// that rank's iteration. Counter-based: reproducible and random-access.
pub fn epoch_z(seed: u64, rank: usize, epoch: usize) -> f64 {
    Rng::new(seed).derive(rank as u64).derive(epoch as u64).normal()
}

/// Pure-Rust sampling straight from the model.
pub struct DirectSource {
    model: DgemmModel,
    seed: u64,
    stochastic: bool,
}

impl DirectSource {
    pub fn new(model: DgemmModel, _nranks: usize, seed: u64) -> Rc<Self> {
        Rc::new(DirectSource { model, seed, stochastic: true })
    }

    /// Mean-only variant (deterministic).
    pub fn deterministic(model: DgemmModel, _nranks: usize) -> Rc<Self> {
        Rc::new(DirectSource { model, seed: 0, stochastic: false })
    }
}

impl DgemmSource for DirectSource {
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64 {
        if self.stochastic {
            let z = epoch_z(self.seed, rank, epoch).abs();
            let c = self.model.coef(node);
            let (mf, nf, kf) = (m as f64, n as f64, k as f64);
            (c.mu_of(mf, nf, kf) + z * c.sigma_of(mf, nf, kf)).max(0.0)
        } else {
            self.model.mu(node, m, n, k)
        }
    }
}

/// The per-rank program-order schedule a [`Recorder`] captures:
/// `(node, epoch, m, n, k)` per call. Plain data (`Send`) — the batched
/// campaign pipeline ships it from recording workers to the evaluation
/// thread and back into replay workers, while `Recorder` itself stays
/// `Rc`-based and thread-local.
pub type RecordedCalls = Vec<Vec<(u32, u32, u32, u32, u32)>>;

/// Recording pass: returns cheap mean durations and logs every shape.
pub struct Recorder {
    model: DgemmModel,
    /// Per rank: `(node, epoch, m, n, k)` in program order.
    pub calls: RefCell<RecordedCalls>,
}

impl Recorder {
    pub fn new(model: DgemmModel, nranks: usize) -> Rc<Self> {
        Rc::new(Recorder {
            model,
            calls: RefCell::new(vec![Vec::new(); nranks]),
        })
    }

    /// Total recorded calls.
    pub fn total(&self) -> usize {
        self.calls.borrow().iter().map(|v| v.len()).sum()
    }

    /// Clone the recorded schedule out of the recorder.
    pub fn calls_snapshot(&self) -> RecordedCalls {
        self.calls.borrow().clone()
    }

    /// Flatten into one batched runtime request: the `[m, n, k]`
    /// tensors and node indices of [`Recorder::flatten`] (homogeneous
    /// models map every index to 0), the per-(rank, epoch) episodic
    /// noise draw of `seed`, and the model's coefficient table — the
    /// per-point unit `runtime::Artifacts::evaluate_batch` concatenates
    /// across a campaign wave.
    pub fn request(&self, seed: u64) -> DgemmRequest {
        let (mnk, mut idx, rank_epoch) = self.flatten();
        if self.model.nodes.len() == 1 {
            // Physical node ids recorded; a homogeneous model (single
            // entry) is valid for any of them.
            for i in idx.iter_mut() {
                *i = 0;
            }
        }
        let mut z = Vec::with_capacity(rank_epoch.len());
        let mut drawn: HashMap<(u32, u32), f64> = HashMap::new();
        for &(r, e) in &rank_epoch {
            z.push(*drawn.entry((r, e)).or_insert_with(|| {
                epoch_z(seed, r as usize, e as usize)
            }));
        }
        DgemmRequest { mnk, idx, z, coef: self.model.nodes.clone() }
    }

    /// Flatten to the artifact's batched layout:
    /// `(mnk, node_idx, per-call (rank, epoch))`.
    pub fn flatten(&self) -> (Vec<[f32; 3]>, Vec<i32>, Vec<(u32, u32)>) {
        let calls = self.calls.borrow();
        let mut mnk = Vec::with_capacity(self.total());
        let mut idx = Vec::with_capacity(self.total());
        let mut rank_epoch = Vec::with_capacity(self.total());
        for (rank, per_rank) in calls.iter().enumerate() {
            for &(node, epoch, m, n, k) in per_rank {
                mnk.push([m as f32, n as f32, k as f32]);
                idx.push(node as i32);
                rank_epoch.push((rank as u32, epoch));
            }
        }
        (mnk, idx, rank_epoch)
    }
}

impl DgemmSource for Recorder {
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64 {
        self.calls.borrow_mut()[rank]
            .push((node as u32, epoch as u32, m as u32, n as u32, k as u32));
        self.model.mu(node, m, n, k)
    }
}

/// Replay divergence diagnostics: the replay pass requested a dgemm
/// call that does not match the recorded schedule. Since HPL's control
/// flow is data-independent this is always a determinism bug, and it
/// means pooled durations would be misattributed — the replay must
/// abort. [`PoolSource`] panics with this error's rendering (the
/// per-point path), and records it for the batched campaign pipeline
/// to surface as a structured `ExecError` after catching the unwind.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReplayError {
    /// The rank whose replay diverged.
    pub rank: usize,
    /// Position in the rank's recorded program-order schedule.
    pub call_index: usize,
    /// Recorded `(node, epoch, m, n, k)` at this position (`None`: the
    /// replay ran past the end of the recorded schedule). The full
    /// tuple travels so a divergence in node or epoch alone is just as
    /// diagnosable as a shape mismatch.
    pub expected: Option<(usize, usize, usize, usize, usize)>,
    /// The `(node, epoch, m, n, k)` the replay actually requested.
    pub observed: (usize, usize, usize, usize, usize),
}

impl ReplayError {
    /// The iteration (epoch) the diverging call was issued in.
    pub fn epoch(&self) -> usize {
        self.observed.1
    }
}

impl std::fmt::Display for ReplayError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (on, oe, om, onn, ok) = self.observed;
        match self.expected {
            Some((en, ee, em, enn, ek)) => write!(
                f,
                "rank {} epoch {oe} call {}: replay diverged from recording \
                 — expected (node, epoch, m, n, k) = ({en}, {ee}, {em}, {enn}, \
                 {ek}), observed ({on}, {oe}, {om}, {onn}, {ok})",
                self.rank, self.call_index
            ),
            None => write!(
                f,
                "rank {} epoch {oe} call {}: replay ran past the recorded \
                 schedule — observed (node, epoch, m, n, k) = ({on}, {oe}, \
                 {om}, {onn}, {ok})",
                self.rank, self.call_index
            ),
        }
    }
}

impl std::error::Error for ReplayError {}

/// Replay pass: pops pre-evaluated durations per rank in program order,
/// verifying on every pop that the replay visits exactly the recorded
/// schedule (cheap; always on).
pub struct PoolSource {
    durations: RefCell<Vec<std::vec::IntoIter<f64>>>,
    shapes: RecordedCalls,
    cursor: RefCell<Vec<usize>>,
    /// The structured divergence behind the last panic, if any.
    failure: RefCell<Option<ReplayError>>,
}

impl PoolSource {
    /// `durations` flattened in the same order as `Recorder::flatten`.
    pub fn new(recorder: &Recorder, flat_durations: &[f32]) -> Rc<Self> {
        let durs: Vec<f64> = flat_durations.iter().map(|&d| d as f64).collect();
        Self::from_calls(recorder.calls_snapshot(), &durs)
    }

    /// Per-point entry of the batched campaign pipeline: a recorded
    /// schedule plus its flattened f64 durations (same order as
    /// `Recorder::flatten`).
    pub fn from_calls(calls: RecordedCalls, flat_durations: &[f64]) -> Rc<Self> {
        let mut per_rank = Vec::with_capacity(calls.len());
        let mut off = 0usize;
        for rank_calls in &calls {
            let n = rank_calls.len();
            let durs: Vec<f64> = flat_durations[off..off + n].to_vec();
            per_rank.push(durs.into_iter());
            off += n;
        }
        assert_eq!(off, flat_durations.len(), "pool size mismatch");
        Rc::new(PoolSource {
            durations: RefCell::new(per_rank),
            cursor: RefCell::new(vec![0; calls.len()]),
            shapes: calls,
            failure: RefCell::new(None),
        })
    }

    /// The structured divergence, if a [`DgemmSource::next`] call on
    /// this pool panicked. The batched campaign pipeline catches the
    /// unwind and reads this to report an `ExecError` instead of
    /// crashing the whole campaign.
    pub fn failure(&self) -> Option<ReplayError> {
        self.failure.borrow().clone()
    }
}

impl DgemmSource for PoolSource {
    fn next(&self, rank: usize, node: usize, epoch: usize, m: usize, n: usize, k: usize) -> f64 {
        let mut cur = self.cursor.borrow_mut();
        let i = cur[rank];
        let expect = self.shapes[rank].get(i).copied();
        let matches = expect
            == Some((node as u32, epoch as u32, m as u32, n as u32, k as u32));
        if !matches {
            let err = ReplayError {
                rank,
                call_index: i,
                expected: expect.map(|(en, ee, em, enn, ek)| {
                    (en as usize, ee as usize, em as usize, enn as usize, ek as usize)
                }),
                observed: (node, epoch, m, n, k),
            };
            *self.failure.borrow_mut() = Some(err.clone());
            panic!("{err}");
        }
        cur[rank] = i + 1;
        drop(cur);
        self.durations.borrow_mut()[rank]
            .next()
            .expect("duration pool in sync with the verified schedule")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::model::NodeCoef;

    fn model() -> DgemmModel {
        DgemmModel {
            nodes: vec![
                NodeCoef {
                    mu: [1e-11, 0.0, 0.0, 0.0, 1e-6],
                    sigma: [3e-13, 0.0, 0.0, 0.0, 0.0],
                },
                NodeCoef {
                    mu: [2e-11, 0.0, 0.0, 0.0, 1e-6],
                    sigma: [0.0; 5],
                },
            ],
        }
    }

    #[test]
    fn direct_streams_are_independent_per_rank_and_epoch() {
        let s = DirectSource::new(model(), 2, 42);
        let a = s.next(0, 0, 0, 100, 100, 100);
        let b = s.next(1, 0, 0, 100, 100, 100);
        assert_ne!(a, b);
        // Same (rank, epoch) -> same noise draw (episodic model).
        assert_eq!(a, s.next(0, 0, 0, 100, 100, 100));
        // Different epoch -> different draw.
        assert_ne!(a, s.next(0, 0, 1, 100, 100, 100));
        // Re-creating with the same seed replays identically.
        let s2 = DirectSource::new(model(), 2, 42);
        assert_eq!(s2.next(0, 0, 0, 100, 100, 100), a);
    }

    #[test]
    fn epoch_noise_scales_whole_iteration() {
        // With sigma proportional to mu, two calls of one epoch see the
        // same slowdown factor: d1/mu1 == d2/mu2.
        let m = model();
        let s = DirectSource::new(m.clone(), 1, 7);
        let d1 = s.next(0, 0, 3, 1000, 64, 64);
        let d2 = s.next(0, 0, 3, 2000, 64, 64);
        let r1 = d1 / m.mu(0, 1000, 64, 64);
        let r2 = d2 / m.mu(0, 2000, 64, 64);
        // mu has an intercept so ratios are close, not identical.
        assert!((r1 - r2).abs() < 0.02, "{r1} vs {r2}");
    }

    #[test]
    fn recorder_captures_program_order() {
        let r = Recorder::new(model(), 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(1, 1, 0, 5, 5, 5);
        r.next(0, 0, 1, 11, 21, 31);
        let (mnk, idx, rank_epoch) = r.flatten();
        assert_eq!(mnk[0], [10.0, 20.0, 30.0]);
        assert_eq!(mnk[1], [11.0, 21.0, 31.0]);
        assert_eq!(mnk[2], [5.0, 5.0, 5.0]);
        assert_eq!(idx, vec![0, 0, 1]);
        assert_eq!(rank_epoch, vec![(0, 0), (0, 1), (1, 0)]);
        assert_eq!(r.total(), 3);
    }

    #[test]
    fn pool_replays_in_order_and_verifies_shapes() {
        let r = Recorder::new(model(), 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(0, 0, 1, 11, 21, 31);
        r.next(1, 1, 0, 5, 5, 5);
        let pool = PoolSource::new(&r, &[1.0, 2.0, 3.0]);
        assert_eq!(pool.next(0, 0, 0, 10, 20, 30), 1.0);
        assert_eq!(pool.next(1, 1, 0, 5, 5, 5), 3.0);
        assert_eq!(pool.next(0, 0, 1, 11, 21, 31), 2.0);
    }

    #[test]
    #[should_panic(expected = "diverged")]
    fn pool_panics_on_shape_divergence() {
        let r = Recorder::new(model(), 1);
        r.next(0, 0, 0, 10, 20, 30);
        let pool = PoolSource::new(&r, &[1.0]);
        pool.next(0, 0, 0, 99, 20, 30);
    }

    #[test]
    fn divergence_is_recorded_structured() {
        let r = Recorder::new(model(), 1);
        r.next(0, 0, 2, 10, 20, 30);
        let pool = PoolSource::new(&r, &[1.0]);
        let run = {
            let pool = pool.clone();
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                pool.next(0, 0, 2, 99, 20, 30);
            }))
        };
        assert!(run.is_err());
        let err = pool.failure().expect("divergence recorded");
        assert_eq!(err.rank, 0);
        assert_eq!(err.epoch(), 2);
        assert_eq!(err.call_index, 0);
        assert_eq!(err.expected, Some((0, 2, 10, 20, 30)));
        assert_eq!(err.observed, (0, 2, 99, 20, 30));
        let msg = err.to_string();
        assert!(
            msg.contains("(0, 2, 10, 20, 30)") && msg.contains("(0, 2, 99, 20, 30)"),
            "{msg}"
        );
    }

    #[test]
    fn running_past_the_schedule_is_recorded_structured() {
        let r = Recorder::new(model(), 1);
        r.next(0, 0, 0, 10, 20, 30);
        let pool = PoolSource::new(&r, &[1.0]);
        assert_eq!(pool.next(0, 0, 0, 10, 20, 30), 1.0);
        let run = {
            let pool = pool.clone();
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                pool.next(0, 0, 1, 10, 20, 30);
            }))
        };
        assert!(run.is_err());
        let err = pool.failure().expect("overrun recorded");
        assert_eq!(err.expected, None);
        assert_eq!(err.call_index, 1);
        assert_eq!(err.epoch(), 1);
        assert!(err.to_string().contains("ran past"), "{err}");
    }

    #[test]
    fn pool_from_calls_replays_like_pool_from_recorder() {
        let r = Recorder::new(model(), 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(1, 1, 0, 5, 5, 5);
        let direct = PoolSource::new(&r, &[1.5, 2.5]);
        let rebuilt = PoolSource::from_calls(r.calls_snapshot(), &[1.5, 2.5]);
        assert_eq!(direct.next(0, 0, 0, 10, 20, 30), 1.5);
        assert_eq!(rebuilt.next(0, 0, 0, 10, 20, 30), 1.5);
        assert_eq!(rebuilt.next(1, 1, 0, 5, 5, 5), 2.5);
    }

    #[test]
    fn request_flattens_draws_and_coefficients() {
        let r = Recorder::new(model(), 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(0, 0, 0, 11, 21, 31); // same (rank, epoch): same draw
        r.next(1, 1, 1, 5, 5, 5);
        let req = r.request(42);
        assert_eq!(req.calls(), 3);
        assert_eq!(req.mnk[0], [10.0, 20.0, 30.0]);
        assert_eq!(req.idx, vec![0, 0, 1]);
        assert_eq!(req.coef.len(), 2, "heterogeneous table travels whole");
        assert_eq!(req.z[0], req.z[1], "episodic draw shared within an epoch");
        assert_eq!(req.z[0], epoch_z(42, 0, 0));
        assert_eq!(req.z[2], epoch_z(42, 1, 1));
    }

    #[test]
    fn request_maps_homogeneous_models_to_index_zero() {
        let m = DgemmModel::homogeneous(crate::blas::NodeCoef::naive(1e-11));
        let r = Recorder::new(m, 2);
        r.next(0, 0, 0, 10, 20, 30);
        r.next(1, 3, 0, 5, 5, 5); // physical node 3
        let req = r.request(7);
        assert_eq!(req.idx, vec![0, 0]);
        assert_eq!(req.coef.len(), 1);
    }
}
