//! Coefficient containers for the polynomial dgemm model and the linear
//! auxiliary-kernel models.

use crate::stats::json::Json;

/// Number of polynomial coefficients: `[MNK, MN, MK, NK, 1]`.
pub const N_COEF: usize = 5;

/// Per-node coefficients: mean polynomial + sigma polynomial.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeCoef {
    pub mu: [f64; N_COEF],
    pub sigma: [f64; N_COEF],
}

impl NodeCoef {
    /// A deterministic model with only the MNK term (the "naive" model
    /// of Fig. 3: `1/flops-rate * M*N*K`).
    pub fn naive(inv_rate: f64) -> NodeCoef {
        NodeCoef { mu: [inv_rate, 0.0, 0.0, 0.0, 0.0], sigma: [0.0; N_COEF] }
    }

    /// Evaluate the mean polynomial.
    pub fn mu_of(&self, m: f64, n: f64, k: f64) -> f64 {
        poly(&self.mu, m, n, k)
    }

    /// Evaluate the sigma polynomial (clamped at 0).
    pub fn sigma_of(&self, m: f64, n: f64, k: f64) -> f64 {
        poly(&self.sigma, m, n, k).max(0.0)
    }

    /// Zero out the variability (used to build deterministic variants of
    /// a calibrated model — dashed line (b) of Fig. 5).
    pub fn deterministic(mut self) -> NodeCoef {
        self.sigma = [0.0; N_COEF];
        self
    }

    /// Serialize for campaign manifests (see `coordinator::manifest`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mu", Json::arr_f64(&self.mu)),
            ("sigma", Json::arr_f64(&self.sigma)),
        ])
    }

    /// Inverse of [`NodeCoef::to_json`]; `None` unless both polynomials
    /// have exactly [`N_COEF`] coefficients.
    pub fn from_json(v: &Json) -> Option<NodeCoef> {
        Some(NodeCoef {
            mu: v.get("mu")?.f64_vec()?.try_into().ok()?,
            sigma: v.get("sigma")?.f64_vec()?.try_into().ok()?,
        })
    }

    /// Convert to the f32 feature-lane layout of the XLA artifacts
    /// (5 real coefficients padded to 8 lanes).
    pub fn to_f32_lanes(&self) -> ([f32; 8], [f32; 8]) {
        let mut mu = [0f32; 8];
        let mut sg = [0f32; 8];
        for i in 0..N_COEF {
            mu[i] = self.mu[i] as f32;
            sg[i] = self.sigma[i] as f32;
        }
        (mu, sg)
    }
}

fn poly(c: &[f64; N_COEF], m: f64, n: f64, k: f64) -> f64 {
    c[0] * m * n * k + c[1] * m * n + c[2] * m * k + c[3] * n * k + c[4]
}

/// The dgemm model for a whole platform: one [`NodeCoef`] per node (a
/// single entry means a homogeneous model).
#[derive(Clone, Debug)]
pub struct DgemmModel {
    pub nodes: Vec<NodeCoef>,
}

impl DgemmModel {
    pub fn homogeneous(c: NodeCoef) -> DgemmModel {
        DgemmModel { nodes: vec![c] }
    }

    /// Coefficients of `node` (a single-entry model is homogeneous and
    /// valid for any node id).
    ///
    /// Node-count agreement between the model, the topology and the
    /// rank placement is checked up front by `SimPoint::validate` in
    /// the campaign layer; this accessor still guards the raw index so
    /// a mismatched hand-built model fails with a diagnosis instead of
    /// a bare out-of-bounds panic deep inside the driver.
    pub fn coef(&self, node: usize) -> &NodeCoef {
        if self.nodes.len() == 1 {
            &self.nodes[0]
        } else {
            self.nodes.get(node).unwrap_or_else(|| {
                panic!(
                    "heterogeneous dgemm model covers {} node(s) but node {node} was \
                     requested — topology/rpn and model node counts disagree (run \
                     SimPoint::validate before simulating)",
                    self.nodes.len()
                )
            })
        }
    }

    /// Mean duration on `node`.
    pub fn mu(&self, node: usize, m: usize, n: usize, k: usize) -> f64 {
        self.coef(node).mu_of(m as f64, n as f64, k as f64).max(0.0)
    }

    /// Sample a stochastic duration on `node` (pure-Rust path).
    pub fn sample(
        &self,
        node: usize,
        m: usize,
        n: usize,
        k: usize,
        rng: &mut crate::stats::Rng,
    ) -> f64 {
        let c = self.coef(node);
        let (mf, nf, kf) = (m as f64, n as f64, k as f64);
        rng.half_normal(c.mu_of(mf, nf, kf), c.sigma_of(mf, nf, kf)).max(0.0)
    }

    /// Strip all variability.
    pub fn deterministic(&self) -> DgemmModel {
        DgemmModel { nodes: self.nodes.iter().map(|c| c.deterministic()).collect() }
    }

    /// Collapse to a single global model (average of node coefficients)
    /// — the "homogeneous" degradation used by Fig. 5's naive model.
    pub fn homogenized(&self) -> DgemmModel {
        let n = self.nodes.len() as f64;
        let mut mu = [0.0; N_COEF];
        let mut sigma = [0.0; N_COEF];
        for c in &self.nodes {
            for i in 0..N_COEF {
                mu[i] += c.mu[i] / n;
                sigma[i] += c.sigma[i] / n;
            }
        }
        DgemmModel::homogeneous(NodeCoef { mu, sigma })
    }

    /// Serialize for campaign manifests (see `coordinator::manifest`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "nodes",
            Json::Arr(self.nodes.iter().map(NodeCoef::to_json).collect()),
        )])
    }

    /// Inverse of [`DgemmModel::to_json`]; `None` on an empty node list
    /// (a model with no coefficients cannot be evaluated).
    pub fn from_json(v: &Json) -> Option<DgemmModel> {
        let nodes: Option<Vec<NodeCoef>> =
            v.get("nodes")?.as_arr()?.iter().map(NodeCoef::from_json).collect();
        let nodes = nodes?;
        if nodes.is_empty() {
            return None;
        }
        Some(DgemmModel { nodes })
    }
}

/// `duration = slope * size + intercept` (deterministic).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearModel {
    pub slope: f64,
    pub intercept: f64,
}

impl LinearModel {
    pub fn of(&self, size: f64) -> f64 {
        (self.slope * size + self.intercept).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    #[test]
    fn poly_evaluation() {
        let c = NodeCoef {
            mu: [1e-11, 1e-9, 0.0, 0.0, 1e-5],
            sigma: [0.0; N_COEF],
        };
        let got = c.mu_of(100.0, 200.0, 50.0);
        let want = 1e-11 * (100.0 * 200.0 * 50.0) + 1e-9 * (100.0 * 200.0) + 1e-5;
        assert!((got - want).abs() < 1e-15, "{got} vs {want}");
    }

    #[test]
    fn naive_model_is_pure_mnk() {
        let c = NodeCoef::naive(1.029e-11);
        assert_eq!(c.mu_of(10.0, 10.0, 10.0), 1.029e-11 * 1000.0);
        assert_eq!(c.sigma_of(1e4, 1e4, 1e4), 0.0);
    }

    #[test]
    fn sample_at_least_mu_and_varies() {
        let model = DgemmModel::homogeneous(NodeCoef {
            mu: [1e-11, 0.0, 0.0, 0.0, 0.0],
            sigma: [3e-13, 0.0, 0.0, 0.0, 0.0],
        });
        let mut rng = Rng::new(1);
        let mu = model.mu(0, 1000, 1000, 100);
        let a = model.sample(0, 1000, 1000, 100, &mut rng);
        let b = model.sample(0, 1000, 1000, 100, &mut rng);
        assert!(a >= mu && b >= mu);
        assert_ne!(a, b);
    }

    #[test]
    fn homogenized_averages() {
        let m = DgemmModel {
            nodes: vec![
                NodeCoef::naive(1.0e-11),
                NodeCoef::naive(3.0e-11),
            ],
        };
        let h = m.homogenized();
        assert_eq!(h.nodes.len(), 1);
        assert!((h.nodes[0].mu[0] - 2.0e-11).abs() < 1e-24);
    }

    #[test]
    fn per_node_lookup() {
        let m = DgemmModel {
            nodes: vec![NodeCoef::naive(1.0e-11), NodeCoef::naive(2.0e-11)],
        };
        assert!(m.mu(1, 100, 100, 100) > m.mu(0, 100, 100, 100));
    }

    #[test]
    fn f32_lane_conversion() {
        let c = NodeCoef { mu: [1.0, 2.0, 3.0, 4.0, 5.0], sigma: [0.1; 5] };
        let (mu, sg) = c.to_f32_lanes();
        assert_eq!(mu[..5], [1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(mu[5..], [0.0, 0.0, 0.0]);
        assert_eq!(sg[0], 0.1f32);
    }

    #[test]
    fn json_roundtrip_exact_coefficients() {
        let m = DgemmModel {
            nodes: vec![
                NodeCoef {
                    mu: [1.0293e-11, 2e-9, -3e-10, 0.0, 5.7e-7],
                    sigma: [3.1e-13, 0.0, 0.0, 1e-12, 0.0],
                },
                NodeCoef::naive(2.5e-11),
            ],
        };
        let back =
            DgemmModel::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(m.nodes, back.nodes);
    }

    #[test]
    fn json_rejects_bad_shapes() {
        assert!(DgemmModel::from_json(&Json::parse("{\"nodes\":[]}").unwrap()).is_none());
        let short = r#"{"nodes":[{"mu":[1,2,3],"sigma":[0,0,0,0,0]}]}"#;
        assert!(DgemmModel::from_json(&Json::parse(short).unwrap()).is_none());
    }

    #[test]
    fn linear_model_clamps() {
        let l = LinearModel { slope: -1.0, intercept: 0.5 };
        assert_eq!(l.of(10.0), 0.0);
        let l2 = LinearModel { slope: 2e-10, intercept: 1e-7 };
        assert!((l2.of(1e6) - 2.001e-4).abs() < 1e-12);
    }
}
