//! Synchronization cells: broadcast signals and join handles.

use std::cell::RefCell;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

/// One-shot broadcast flag: tasks `wait().await` until someone `set()`s.
///
/// Used for flow completion, rendezvous handshakes, and panel-arrival
/// notifications. Setting twice is idempotent.
#[derive(Clone, Default)]
pub struct Signal {
    inner: Rc<RefCell<SignalState>>,
}

#[derive(Default)]
struct SignalState {
    set: bool,
    wakers: Vec<Waker>,
}

impl Signal {
    pub fn new() -> Signal {
        Signal::default()
    }

    /// Fire the signal, waking all current and future waiters.
    pub fn set(&self) {
        let mut s = self.inner.borrow_mut();
        s.set = true;
        for w in s.wakers.drain(..) {
            w.wake();
        }
    }

    pub fn is_set(&self) -> bool {
        self.inner.borrow().set
    }

    /// Future resolving once the signal is set.
    pub fn wait(&self) -> SignalWait {
        SignalWait { inner: self.inner.clone() }
    }
}

pub struct SignalWait {
    inner: Rc<RefCell<SignalState>>,
}

impl Future for SignalWait {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let mut s = self.inner.borrow_mut();
        if s.set {
            Poll::Ready(())
        } else {
            s.wakers.push(cx.waker().clone());
            Poll::Pending
        }
    }
}

/// State shared between a `spawn_join` task and its handle.
pub struct JoinState<T> {
    pub value: Option<T>,
    pub wakers: Vec<Waker>,
}

impl<T> Default for JoinState<T> {
    fn default() -> Self {
        JoinState { value: None, wakers: Vec::new() }
    }
}

/// Awaitable handle on a spawned task's result.
pub struct JoinHandle<T> {
    state: Rc<RefCell<JoinState<T>>>,
}

impl<T> JoinHandle<T> {
    pub(crate) fn new(state: Rc<RefCell<JoinState<T>>>) -> Self {
        JoinHandle { state }
    }

    /// Non-blocking check.
    pub fn is_done(&self) -> bool {
        self.state.borrow().value.is_some()
    }
}

impl<T> Future for JoinHandle<T> {
    type Output = T;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        let mut s = self.state.borrow_mut();
        match s.value.take() {
            Some(v) => Poll::Ready(v),
            None => {
                s.wakers.push(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Sim;
    use std::cell::Cell;

    #[test]
    fn signal_wakes_multiple_waiters() {
        let sim = Sim::new();
        let sig = Signal::new();
        let count = Rc::new(Cell::new(0));
        for _ in 0..5 {
            let sg = sig.clone();
            let c = count.clone();
            let s = sim.clone();
            sim.spawn(async move {
                sg.wait().await;
                assert_eq!(s.now(), 2.0);
                c.set(c.get() + 1);
            });
        }
        let s = sim.clone();
        sim.spawn(async move {
            s.sleep(2.0).await;
            sig.set();
        });
        sim.run();
        assert_eq!(count.get(), 5);
    }

    #[test]
    fn wait_after_set_is_immediate() {
        let sim = Sim::new();
        let sig = Signal::new();
        sig.set();
        let s = sim.clone();
        sim.spawn(async move {
            sig.wait().await;
            assert_eq!(s.now(), 0.0);
        });
        sim.run();
    }
}
