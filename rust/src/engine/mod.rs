//! Deterministic virtual-time discrete-event engine.
//!
//! Every simulated MPI rank is an `async` task driven by a
//! single-threaded executor whose clock is *simulated time*: awaiting
//! [`Sim::sleep`] advances nothing in real time, it merely schedules the
//! task's waker on the event heap. This is the same execution model as
//! SimGrid/SMPI's mutual-exclusion threads, with Rust futures instead of
//! contexts: exactly one task runs at a time, and time only advances
//! when every runnable task has yielded.
//!
//! The engine is deterministic: ties on the event heap are broken by a
//! monotonically increasing sequence number, so a simulation with the
//! same seed replays the exact same schedule.

mod cell;
mod sim;

pub use cell::{JoinHandle, Signal};
pub use sim::{Sim, SimStats};
