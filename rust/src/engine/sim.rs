//! The executor: task storage, event heap, virtual clock.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Wake, Waker};

use super::cell::{JoinHandle, JoinState};

type BoxFuture = Pin<Box<dyn Future<Output = ()>>>;

/// A timer entry: wake `waker` at simulated time `at`.
struct Timer {
    at: f64,
    seq: u64,
    waker: Waker,
}

impl PartialEq for Timer {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Timer {}
impl PartialOrd for Timer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Total order: by time then sequence (f64 times are finite by
        // construction — asserted on push).
        self.at
            .partial_cmp(&other.at)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

struct Kernel {
    now: f64,
    seq: u64,
    timers: BinaryHeap<Reverse<Timer>>,
    tasks: Vec<Option<BoxFuture>>,
    /// Cached waker per task (one Arc allocation per task, not per poll).
    wakers: Vec<Option<Waker>>,
    live: usize,
    events_fired: u64,
}

/// Cross-task wake queue (single-threaded in practice; the Mutex exists
/// because `std::task::Wake` demands `Send + Sync`).
type WakeQueue = Arc<Mutex<Vec<usize>>>;

struct TaskWaker {
    id: usize,
    queue: WakeQueue,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.queue.lock().unwrap().push(self.id);
    }
    fn wake_by_ref(self: &Arc<Self>) {
        self.queue.lock().unwrap().push(self.id);
    }
}

/// Counters exposed after a run (used by the perf harness).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Number of timer events fired.
    pub events: u64,
    /// Number of task polls performed.
    pub polls: u64,
    /// Tasks spawned over the lifetime of the simulation.
    pub tasks: usize,
}

/// Handle on a simulation: clonable, cheap, single-threaded.
#[derive(Clone)]
pub struct Sim {
    k: Rc<RefCell<Kernel>>,
    queue: WakeQueue,
    polls: Rc<RefCell<u64>>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Sim {
        Sim::with_capacity(0)
    }

    /// Build a simulation with task storage pre-allocated for `tasks`
    /// tasks (e.g. one per MPI rank). Campaign sweeps construct one
    /// engine per point, so avoiding the repeated grow-reallocations of
    /// the task and waker vectors matters at scale.
    pub fn with_capacity(tasks: usize) -> Sim {
        Sim {
            k: Rc::new(RefCell::new(Kernel {
                now: 0.0,
                seq: 0,
                timers: BinaryHeap::with_capacity(tasks),
                tasks: Vec::with_capacity(tasks),
                wakers: Vec::with_capacity(tasks),
                live: 0,
                events_fired: 0,
            })),
            queue: Arc::new(Mutex::new(Vec::with_capacity(tasks))),
            polls: Rc::new(RefCell::new(0)),
        }
    }

    /// Current simulated time in seconds.
    pub fn now(&self) -> f64 {
        self.k.borrow().now
    }

    /// Spawn a task; it becomes runnable immediately.
    pub fn spawn<F>(&self, fut: F)
    where
        F: Future<Output = ()> + 'static,
    {
        let id = {
            let mut k = self.k.borrow_mut();
            k.tasks.push(Some(Box::pin(fut)));
            k.wakers.push(None);
            k.live += 1;
            k.tasks.len() - 1
        };
        self.queue.lock().unwrap().push(id);
    }

    /// Spawn a task returning a value, with a joinable handle.
    pub fn spawn_join<F, T>(&self, fut: F) -> JoinHandle<T>
    where
        F: Future<Output = T> + 'static,
        T: 'static,
    {
        let state = Rc::new(RefCell::new(JoinState::default()));
        let state2 = state.clone();
        self.spawn(async move {
            let v = fut.await;
            let mut s = state2.borrow_mut();
            s.value = Some(v);
            for w in s.wakers.drain(..) {
                w.wake();
            }
        });
        JoinHandle::new(state)
    }

    /// Sleep until simulated time `now + dur`.
    pub fn sleep(&self, dur: f64) -> Delay {
        debug_assert!(dur >= 0.0 && dur.is_finite(), "bad delay {dur}");
        let at = self.k.borrow().now + dur;
        Delay { k: self.k.clone(), at, armed: false }
    }

    /// Sleep until an absolute simulated time.
    pub fn sleep_until(&self, at: f64) -> Delay {
        Delay { k: self.k.clone(), at, armed: false }
    }

    /// Register a waker to fire at absolute time `at` (used by the
    /// network model to (re)schedule flow completions).
    pub fn wake_at(&self, at: f64, waker: Waker) {
        let mut k = self.k.borrow_mut();
        assert!(at.is_finite(), "non-finite timer {at}");
        let seq = k.seq;
        k.seq += 1;
        k.timers.push(Reverse(Timer { at, seq, waker }));
    }

    /// Run until all tasks complete (or the simulation deadlocks).
    ///
    /// Returns the final simulated time. Panics on deadlock — a
    /// deadlock is always a bug in a protocol implementation.
    pub fn run(&self) -> f64 {
        self.run_with_stats().0
    }

    /// Run to completion and also return engine counters.
    pub fn run_with_stats(&self) -> (f64, SimStats) {
        // Double-buffered wake queue: `scratch` swaps with the shared
        // queue under the lock, is drained without it, and swaps back
        // on the next round. Both buffers keep their capacity, so
        // steady-state polling allocates nothing — the old
        // `mem::take(&mut *q)` left a fresh zero-capacity Vec behind
        // and thus re-allocated the queue on every quiescence round
        // (millions of times in a large HPL run).
        let mut scratch: Vec<usize> = Vec::new();
        loop {
            // Poll runnable tasks to quiescence.
            loop {
                {
                    let mut q = self.queue.lock().unwrap();
                    std::mem::swap(&mut *q, &mut scratch);
                }
                if scratch.is_empty() {
                    break;
                }
                for id in scratch.drain(..) {
                    self.poll_task(id);
                }
            }
            // Advance virtual time to the next timer.
            let fired = {
                let mut k = self.k.borrow_mut();
                match k.timers.pop() {
                    Some(Reverse(t)) => {
                        debug_assert!(t.at >= k.now, "time went backwards");
                        k.now = t.at.max(k.now);
                        k.events_fired += 1;
                        Some(t.waker)
                    }
                    None => None,
                }
            };
            match fired {
                Some(w) => w.wake(),
                None => break,
            }
        }
        let k = self.k.borrow();
        if k.live != 0 {
            panic!(
                "simulation deadlock at t={}: {} task(s) blocked with no pending event",
                k.now, k.live
            );
        }
        let stats = SimStats {
            events: k.events_fired,
            polls: *self.polls.borrow(),
            tasks: k.tasks.len(),
        };
        (k.now, stats)
    }

    fn poll_task(&self, id: usize) {
        // Take the future — and its cached waker — out so polling can
        // re-borrow the kernel. Moving the waker instead of cloning it
        // saves an Arc refcount round-trip on every poll; it goes back
        // into its slot (same identity) when the task stays pending.
        let (fut, waker) = {
            let mut k = self.k.borrow_mut();
            let fut = match k.tasks.get_mut(id) {
                Some(slot) => slot.take(),
                None => None,
            };
            match fut {
                Some(f) => {
                    let w = k.wakers[id].take().unwrap_or_else(|| {
                        Waker::from(Arc::new(TaskWaker {
                            id,
                            queue: self.queue.clone(),
                        }))
                    });
                    (Some(f), Some(w))
                }
                None => (None, None),
            }
        };
        let Some(mut fut) = fut else { return };
        let waker = waker.unwrap();
        *self.polls.borrow_mut() += 1;
        let mut cx = Context::from_waker(&waker);
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(()) => {
                let mut k = self.k.borrow_mut();
                k.live -= 1;
                // Slot stays None: task is finished; its waker (still in
                // the local) drops here instead of going back.
            }
            Poll::Pending => {
                let mut k = self.k.borrow_mut();
                k.tasks[id] = Some(fut);
                k.wakers[id] = Some(waker);
            }
        }
    }
}

/// Future that completes at a fixed simulated time.
pub struct Delay {
    k: Rc<RefCell<Kernel>>,
    at: f64,
    /// Whether this delay's timer is already on the heap. A pending
    /// delay re-polled by a spurious wake (a task woken by some *other*
    /// source while suspended here) used to push a fresh timer on every
    /// poll, leaving duplicate heap entries and firing spurious wakes at
    /// `at`; the timer is registered exactly once now.
    armed: bool,
}

impl Future for Delay {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let this = self.get_mut();
        let mut k = this.k.borrow_mut();
        if k.now >= this.at {
            Poll::Ready(())
        } else {
            if !this.armed {
                this.armed = true;
                let seq = k.seq;
                k.seq += 1;
                k.timers.push(Reverse(Timer {
                    at: this.at,
                    seq,
                    waker: cx.waker().clone(),
                }));
            }
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn time_advances_only_by_sleep() {
        let sim = Sim::new();
        let s = sim.clone();
        let out = Rc::new(Cell::new(0.0));
        let o = out.clone();
        sim.spawn(async move {
            s.sleep(1.5).await;
            s.sleep(2.5).await;
            o.set(s.now());
        });
        let end = sim.run();
        assert_eq!(end, 4.0);
        assert_eq!(out.get(), 4.0);
    }

    #[test]
    fn tasks_interleave_deterministically() {
        let sim = Sim::new();
        let log: Rc<RefCell<Vec<(f64, u32)>>> = Default::default();
        for id in 0..3u32 {
            let s = sim.clone();
            let l = log.clone();
            sim.spawn(async move {
                for step in 0..3 {
                    s.sleep(1.0 + id as f64 * 0.1).await;
                    l.borrow_mut().push((s.now(), id * 10 + step));
                }
            });
        }
        sim.run();
        let got = log.borrow().clone();
        // Replay must give the identical schedule.
        let sim2 = Sim::new();
        let log2: Rc<RefCell<Vec<(f64, u32)>>> = Default::default();
        for id in 0..3u32 {
            let s = sim2.clone();
            let l = log2.clone();
            sim2.spawn(async move {
                for step in 0..3 {
                    s.sleep(1.0 + id as f64 * 0.1).await;
                    l.borrow_mut().push((s.now(), id * 10 + step));
                }
            });
        }
        sim2.run();
        assert_eq!(got, *log2.borrow());
        // And events must be time-ordered.
        for w in got.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn spawn_join_returns_value() {
        let sim = Sim::new();
        let s = sim.clone();
        let h = sim.spawn_join(async move {
            s.sleep(3.0).await;
            42u64
        });
        let s2 = sim.clone();
        let got = Rc::new(Cell::new(0u64));
        let g = got.clone();
        sim.spawn(async move {
            let v = h.await;
            assert_eq!(s2.now(), 3.0);
            g.set(v);
        });
        sim.run();
        assert_eq!(got.get(), 42);
    }

    #[test]
    fn zero_delay_is_fine() {
        let sim = Sim::new();
        let s = sim.clone();
        sim.spawn(async move {
            s.sleep(0.0).await;
            assert_eq!(s.now(), 0.0);
        });
        assert_eq!(sim.run(), 0.0);
    }

    #[test]
    fn nested_spawn_runs() {
        let sim = Sim::new();
        let s = sim.clone();
        let done = Rc::new(Cell::new(false));
        let d = done.clone();
        sim.spawn(async move {
            s.sleep(1.0).await;
            let s2 = s.clone();
            s.spawn(async move {
                s2.sleep(1.0).await;
                d.set(true);
            });
        });
        assert_eq!(sim.run(), 2.0);
        assert!(done.get());
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn deadlock_panics() {
        let sim = Sim::new();
        let sig = crate::engine::Signal::new();
        let s2 = sig.clone();
        sim.spawn(async move {
            s2.wait().await; // never set
        });
        sim.run();
    }

    #[test]
    fn repolled_delay_registers_one_timer() {
        // Regression: a pending Delay re-polled by spurious wakes (the
        // task is woken twice by external timers while suspended on the
        // delay) must not push duplicate heap entries. Event budget:
        // two provoker timers (t=1, t=2) + exactly one delay timer
        // (t=10) = 3 events. The old every-poll registration fired 5.
        struct Provoker {
            sim: Sim,
            delay: Delay,
            primed: bool,
        }
        impl Future for Provoker {
            type Output = ();
            fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
                let this = self.get_mut();
                if !this.primed {
                    this.primed = true;
                    this.sim.wake_at(1.0, cx.waker().clone());
                    this.sim.wake_at(2.0, cx.waker().clone());
                }
                Pin::new(&mut this.delay).poll(cx)
            }
        }
        let sim = Sim::new();
        let delay = sim.sleep_until(10.0);
        sim.spawn(Provoker { sim: sim.clone(), delay, primed: false });
        let (end, stats) = sim.run_with_stats();
        assert_eq!(end, 10.0);
        assert_eq!(stats.events, 3, "duplicate delay timers on the heap");
        // Initial poll + one per wake (t=1, t=2, t=10).
        assert_eq!(stats.polls, 4);
    }

    #[test]
    fn many_tasks_scale() {
        let sim = Sim::new();
        for i in 0..1000 {
            let s = sim.clone();
            sim.spawn(async move {
                s.sleep(i as f64 * 1e-3).await;
            });
        }
        let (end, stats) = sim.run_with_stats();
        assert!((end - 0.999).abs() < 1e-12);
        assert_eq!(stats.tasks, 1000);
    }
}
