//! `hplsim` binary: CLI front-end over the library (see `coordinator`).

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(hplsim::coordinator::cli::main_with_args(&args));
}
