//! HPL configuration: the full parameter space of the paper's §2.

use crate::stats::json::Json;

/// Panel broadcast algorithm (HPL's six variants, §2 BCAST).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Bcast {
    /// Increasing ring.
    Ring,
    /// Increasing ring, modified: the next root receives first and does
    /// not relay.
    RingM,
    /// Increasing 2-ring: two chains of half length.
    TwoRing,
    /// Increasing 2-ring, modified.
    TwoRingM,
    /// Spread-and-roll (bandwidth optimal); no Iprobe overlap in
    /// HPL 2.1/2.2.
    Long,
    /// Spread-and-roll, modified.
    LongM,
}

impl Bcast {
    pub const ALL: [Bcast; 6] =
        [Bcast::Ring, Bcast::RingM, Bcast::TwoRing, Bcast::TwoRingM, Bcast::Long, Bcast::LongM];

    pub fn name(&self) -> &'static str {
        match self {
            Bcast::Ring => "1ring",
            Bcast::RingM => "1ringM",
            Bcast::TwoRing => "2ring",
            Bcast::TwoRingM => "2ringM",
            Bcast::Long => "long",
            Bcast::LongM => "longM",
        }
    }

    pub fn parse(s: &str) -> Option<Bcast> {
        Bcast::ALL.iter().copied().find(|b| b.name().eq_ignore_ascii_case(s))
    }

    /// Ring variants poll MPI_Iprobe and overlap with the update; the
    /// long variants do not (disabled in HPL 2.1/2.2, see §2).
    pub fn overlaps(&self) -> bool {
        !matches!(self, Bcast::Long | Bcast::LongM)
    }
}

/// Row-swap (pivoting) algorithm, §2 SWAP.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SwapAlg {
    /// Binary exchange along a virtual tree.
    BinExch,
    /// Spread-and-roll ("long" swap; more parallel communications).
    SpreadRoll,
    /// Threshold mix of the two.
    Mix,
}

impl SwapAlg {
    pub const ALL: [SwapAlg; 3] = [SwapAlg::BinExch, SwapAlg::SpreadRoll, SwapAlg::Mix];

    pub fn name(&self) -> &'static str {
        match self {
            SwapAlg::BinExch => "binary-exch",
            SwapAlg::SpreadRoll => "spread-roll",
            SwapAlg::Mix => "mix",
        }
    }

    pub fn parse(s: &str) -> Option<SwapAlg> {
        match s.to_ascii_lowercase().as_str() {
            "binary-exch" | "binexch" | "bin" => Some(SwapAlg::BinExch),
            "spread-roll" | "long" | "spreadroll" => Some(SwapAlg::SpreadRoll),
            "mix" => Some(SwapAlg::Mix),
            _ => None,
        }
    }
}

/// Panel factorization recursion variant (RFACT; PFACT is analogous and
/// folded into the same enum — the paper found neither matters much).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rfact {
    Left,
    Crout,
    Right,
}

impl Rfact {
    pub const ALL: [Rfact; 3] = [Rfact::Left, Rfact::Crout, Rfact::Right];

    pub fn name(&self) -> &'static str {
        match self {
            Rfact::Left => "left",
            Rfact::Crout => "crout",
            Rfact::Right => "right",
        }
    }

    pub fn parse(s: &str) -> Option<Rfact> {
        Rfact::ALL.iter().copied().find(|r| r.name().eq_ignore_ascii_case(s))
    }
}

/// A full HPL run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct HplConfig {
    /// Matrix order.
    pub n: usize,
    /// Blocking factor.
    pub nb: usize,
    /// Process rows.
    pub p: usize,
    /// Process columns.
    pub q: usize,
    /// Look-ahead depth (0 or 1 supported, as in the paper's runs).
    pub depth: usize,
    pub bcast: Bcast,
    pub swap: SwapAlg,
    /// Mix swap: panels with `jb <= swap_threshold` use binary-exchange.
    pub swap_threshold: usize,
    pub rfact: Rfact,
    /// Recursion stopping criterion (HPL's NBMIN).
    pub nbmin: usize,
}

impl HplConfig {
    /// The defaults the paper uses on Dahu (§3.3): NB=128, depth 1,
    /// increasing-2-ring, Crout, binary-exchange.
    pub fn dahu_default(n: usize, p: usize, q: usize) -> HplConfig {
        HplConfig {
            n,
            nb: 128,
            p,
            q,
            depth: 1,
            bcast: Bcast::TwoRing,
            swap: SwapAlg::BinExch,
            swap_threshold: 64,
            rfact: Rfact::Crout,
            nbmin: 8,
        }
    }

    /// Table 1: the Stampede@TACC TOP500 run (June 2013).
    pub fn stampede() -> HplConfig {
        HplConfig {
            n: 3_875_000,
            nb: 1024,
            p: 77,
            q: 78,
            depth: 0,
            bcast: Bcast::LongM,
            swap: SwapAlg::BinExch,
            swap_threshold: 64,
            rfact: Rfact::Crout,
            nbmin: 8,
        }
    }

    /// Table 1: the Theta@ANL TOP500 run (Nov 2017).
    pub fn theta() -> HplConfig {
        HplConfig {
            n: 8_360_352,
            nb: 336,
            p: 32,
            q: 101,
            depth: 0,
            bcast: Bcast::TwoRingM,
            swap: SwapAlg::BinExch,
            swap_threshold: 64,
            rfact: Rfact::Left,
            nbmin: 8,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 || self.nb == 0 || self.p == 0 || self.q == 0 {
            return Err("n, nb, p, q must be positive".into());
        }
        if self.depth > 1 {
            return Err("only look-ahead depth 0 and 1 are supported".into());
        }
        if self.nbmin == 0 || self.nbmin > self.nb {
            return Err("nbmin must be in [1, nb]".into());
        }
        Ok(())
    }

    pub fn nranks(&self) -> usize {
        self.p * self.q
    }

    /// Number of panel iterations.
    pub fn nblocks(&self) -> usize {
        self.n.div_ceil(self.nb)
    }

    /// Width of panel `j`.
    pub fn jb(&self, j: usize) -> usize {
        self.nb.min(self.n - j * self.nb)
    }

    /// LU flop count used for the GFlop/s metric: 2/3 N^3 + 2 N^2.
    pub fn flops(&self) -> f64 {
        let n = self.n as f64;
        2.0 / 3.0 * n * n * n + 2.0 * n * n
    }

    /// Serialize for campaign manifests (see `coordinator::manifest`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("nb", Json::Num(self.nb as f64)),
            ("p", Json::Num(self.p as f64)),
            ("q", Json::Num(self.q as f64)),
            ("depth", Json::Num(self.depth as f64)),
            ("bcast", Json::Str(self.bcast.name().into())),
            ("swap", Json::Str(self.swap.name().into())),
            ("swap_threshold", Json::Num(self.swap_threshold as f64)),
            ("rfact", Json::Str(self.rfact.name().into())),
            ("nbmin", Json::Num(self.nbmin as f64)),
        ])
    }

    /// Inverse of [`HplConfig::to_json`]; `None` on missing fields,
    /// unknown algorithm names, or a config [`Self::validate`] rejects.
    pub fn from_json(v: &Json) -> Option<HplConfig> {
        let cfg = HplConfig {
            n: v.get("n")?.as_usize()?,
            nb: v.get("nb")?.as_usize()?,
            p: v.get("p")?.as_usize()?,
            q: v.get("q")?.as_usize()?,
            depth: v.get("depth")?.as_usize()?,
            bcast: Bcast::parse(v.get("bcast")?.as_str()?)?,
            swap: SwapAlg::parse(v.get("swap")?.as_str()?)?,
            swap_threshold: v.get("swap_threshold")?.as_usize()?,
            rfact: Rfact::parse(v.get("rfact")?.as_str()?)?,
            nbmin: v.get("nbmin")?.as_usize()?,
        };
        cfg.validate().ok()?;
        Some(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for b in Bcast::ALL {
            assert_eq!(Bcast::parse(b.name()), Some(b));
        }
        for s in SwapAlg::ALL {
            assert_eq!(SwapAlg::parse(s.name()), Some(s));
        }
        for r in Rfact::ALL {
            assert_eq!(Rfact::parse(r.name()), Some(r));
        }
        assert_eq!(Bcast::parse("nope"), None);
    }

    #[test]
    fn overlap_capability() {
        assert!(Bcast::TwoRing.overlaps());
        assert!(!Bcast::Long.overlaps());
        assert!(!Bcast::LongM.overlaps());
    }

    #[test]
    fn block_math() {
        let c = HplConfig::dahu_default(1000, 2, 2);
        assert_eq!(c.nblocks(), 8); // ceil(1000/128)
        assert_eq!(c.jb(0), 128);
        assert_eq!(c.jb(7), 1000 - 7 * 128);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn flops_formula() {
        let c = HplConfig::dahu_default(1000, 1, 1);
        let n = 1000f64;
        assert_eq!(c.flops(), 2.0 / 3.0 * n.powi(3) + 2.0 * n * n);
    }

    #[test]
    fn table1_presets() {
        assert_eq!(HplConfig::stampede().nranks(), 6006);
        assert_eq!(HplConfig::theta().nranks(), 3232);
        assert!(HplConfig::stampede().validate().is_ok());
    }

    #[test]
    fn json_roundtrip_all_algorithms() {
        for bcast in Bcast::ALL {
            for swap in SwapAlg::ALL {
                let mut c = HplConfig::dahu_default(4096, 4, 8);
                c.bcast = bcast;
                c.swap = swap;
                c.rfact = Rfact::Left;
                let back =
                    HplConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap())
                        .unwrap();
                assert_eq!(c, back);
            }
        }
    }

    #[test]
    fn json_rejects_malformed() {
        assert!(HplConfig::from_json(&Json::parse("{}").unwrap()).is_none());
        let mut v = HplConfig::dahu_default(4096, 4, 8).to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("bcast".into(), Json::Str("no-such-alg".into()));
        }
        assert!(HplConfig::from_json(&v).is_none());
        // An invalid config (depth > 1) must not deserialize either.
        let mut v = HplConfig::dahu_default(4096, 4, 8).to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("depth".into(), Json::Num(3.0));
        }
        assert!(HplConfig::from_json(&v).is_none());
    }

    #[test]
    fn validate_rejects_bad() {
        let mut c = HplConfig::dahu_default(1000, 2, 2);
        c.depth = 3;
        assert!(c.validate().is_err());
        c.depth = 0;
        c.nbmin = 0;
        assert!(c.validate().is_err());
    }
}
