//! The HPL emulation driver: the per-rank main loop with look-ahead,
//! plus entry points to run a whole simulation (single pass or the
//! record→evaluate→replay production pipeline through the XLA runtime).

use std::rc::Rc;

use super::bcast::BcastOp;
use super::config::HplConfig;
use super::grid::{local_count, Grid};
use super::panel::PanelFact;
use super::swap::swap_bcast;
use crate::blas::{DgemmModel, DgemmSource, KernelModels, PoolSource, Recorder};
use crate::engine::Sim;
use crate::mpi::{CommStats, Ctx, World};
use crate::network::{NetModel, Network, Topology};
use crate::runtime::Artifacts;

/// Message-tag layout: `j << 24 | kind << 16 | seq`.
pub(crate) fn tag(j: usize, kind: u64, seq: u64) -> u64 {
    debug_assert!(seq < 1 << 16);
    ((j as u64) << 24) | (kind << 16) | seq
}

const K_BCAST: u64 = 1;
const K_FACT: u64 = 2;
const K_PRESWAP: u64 = 3;
const K_SWAP: u64 = 4;

/// Draw a dgemm duration for `(rank, node, epoch, m, n, k)` and advance
/// the rank's clock by it, tracing the call *shape* so skeleton replay
/// can re-draw the duration for another point of the same structure
/// class. Every dgemm of the emulation goes through here.
pub(crate) async fn compute_dgemm(
    ctx: &Ctx,
    models: &KernelModels,
    node: usize,
    epoch: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    let d = models.dgemm.next(ctx.rank, node, epoch, m, n, k);
    ctx.compute_dgemm_traced(d, node, epoch, m, n, k).await;
}

/// Outcome of one simulated HPL run. The all-zero `Default` is the
/// placeholder used when a campaign is *planned* (manifest export, see
/// `coordinator::manifest`) rather than executed.
#[derive(Clone, Copy, Debug, Default)]
pub struct HplResult {
    /// Simulated wall-clock of the factorization.
    pub seconds: f64,
    /// (2/3 N^3 + 2 N^2) / seconds / 1e9.
    pub gflops: f64,
    pub comm: CommStats,
    /// Engine events fired (perf diagnostics).
    pub events: u64,
    /// Total dgemm-model invocations.
    pub dgemm_calls: usize,
}

/// Panel broadcast bytes for row `row` at iteration `j`: the row-local
/// panel slice plus pivot bookkeeping.
fn bcast_bytes(cfg: &HplConfig, j: usize, row: usize) -> f64 {
    let jb = cfg.jb(j);
    let mp = local_count(cfg.n, cfg.nb, j, row, cfg.p);
    ((mp * jb + 2 * jb) * 8) as f64
}

fn make_bcast(
    cfg: &HplConfig,
    j: usize,
    row_group: &[usize],
    my_col: usize,
    my_row: usize,
) -> BcastOp {
    let root = j % cfg.q;
    BcastOp::new(
        cfg.bcast,
        row_group.to_vec(),
        my_col,
        root,
        bcast_bytes(cfg, j, my_row),
        tag(j, K_BCAST, 0),
    )
}

/// Trailing update of `nq` local columns with panel `j` (swap + dtrsm +
/// chunked dgemm, polling `bcast_next` between chunks).
#[allow(clippy::too_many_arguments)]
async fn update(
    ctx: &Ctx,
    models: &KernelModels,
    cfg: &HplConfig,
    node: usize,
    j: usize,
    col_group: &[usize],
    my_row: usize,
    mp: usize,
    nq: usize,
    mut bcast_next: Option<&mut BcastOp>,
) {
    let jb = cfg.jb(j);
    if nq > 0 {
        swap_bcast(
            ctx,
            cfg.swap,
            jb,
            cfg.swap_threshold,
            col_group,
            my_row,
            tag(j, K_SWAP, 0),
            (jb * nq * 8) as f64,
        )
        .await;
        ctx.compute(models.dtrsm.of((jb * jb * nq) as f64)).await;
    }
    let mut done_cols = 0usize;
    while done_cols < nq {
        let c = cfg.nb.min(nq - done_cols);
        if mp > 0 {
            compute_dgemm(ctx, models, node, j, mp, c, jb).await;
        }
        done_cols += c;
        if let Some(b) = bcast_next.as_deref_mut() {
            b.poll(ctx).await;
        }
    }
    if nq == 0 {
        if let Some(b) = bcast_next.as_deref_mut() {
            b.poll(ctx).await;
        }
    }
}

/// One rank's HPL program (pdgesv with look-ahead depth 0 or 1).
async fn rank_main(ctx: Ctx, cfg: Rc<HplConfig>, models: KernelModels) {
    let grid = Grid::new(cfg.p, cfg.q);
    let my_row = grid.row_of(ctx.rank);
    let my_col = grid.col_of(ctx.rank);
    let row_group = grid.row_group(my_row);
    let col_group = grid.col_group(my_col);
    let node = ctx.world.node_of(ctx.rank);
    let nblocks = cfg.nblocks();
    let mut pending: Option<BcastOp> = None;

    for j in 0..nblocks {
        let jb = cfg.jb(j);
        let panel_col = j % cfg.q;

        // ---- acquire panel j ----
        match pending.take() {
            Some(mut b) => b.finish(&ctx).await,
            None => {
                if my_col == panel_col {
                    let mp = local_count(cfg.n, cfg.nb, j, my_row, cfg.p);
                    let mut pf = PanelFact::new(
                        &ctx,
                        &models,
                        &col_group,
                        my_row,
                        node,
                        cfg.nbmin,
                        cfg.rfact,
                        tag(j, K_FACT, 0),
                        jb,
                        j,
                    );
                    pf.run(mp, jb).await;
                }
                let mut b = make_bcast(&cfg, j, &row_group, my_col, my_row);
                b.start(&ctx);
                b.finish(&ctx).await;
            }
        }

        // ---- trailing sizes ----
        let mp = local_count(cfg.n, cfg.nb, j + 1, my_row, cfg.p);
        let nq = local_count(cfg.n, cfg.nb, j + 1, my_col, cfg.q);

        let next = j + 1;
        let lookahead = cfg.depth >= 1 && next < nblocks;
        if lookahead {
            let next_col = next % cfg.q;
            let jb_next = cfg.jb(next);
            if my_col == next_col {
                // Pre-update only the next panel's columns...
                if jb_next > 0 {
                    swap_bcast(
                        &ctx,
                        cfg.swap,
                        jb,
                        cfg.swap_threshold,
                        &col_group,
                        my_row,
                        tag(j, K_PRESWAP, 0),
                        (jb * jb_next * 8) as f64,
                    )
                    .await;
                    ctx.compute(models.dtrsm.of((jb * jb * jb_next) as f64)).await;
                    if mp > 0 {
                        compute_dgemm(&ctx, &models, node, j, mp, jb_next, jb).await;
                    }
                }
                // ...then factor panel j+1 immediately.
                let mut pf = PanelFact::new(
                    &ctx,
                    &models,
                    &col_group,
                    my_row,
                    node,
                    cfg.nbmin,
                    cfg.rfact,
                    tag(next, K_FACT, 0),
                    jb_next,
                    next,
                );
                pf.run(mp, jb_next).await;
            }
            let mut b2 = make_bcast(&cfg, next, &row_group, my_col, my_row);
            b2.start(&ctx);
            let nq_rest = if my_col == next_col { nq - jb_next.min(nq) } else { nq };
            update(
                &ctx, &models, &cfg, node, j, &col_group, my_row, mp, nq_rest,
                Some(&mut b2),
            )
            .await;
            pending = Some(b2);
        } else {
            update(&ctx, &models, &cfg, node, j, &col_group, my_row, mp, nq, None)
                .await;
        }
    }
    // Drain a possibly pending broadcast (the last iteration never
    // leaves one, but keep the invariant explicit).
    if let Some(mut b) = pending.take() {
        b.finish(&ctx).await;
    }
}

/// Run a single simulation pass with the given dgemm duration source.
pub fn run_once(
    cfg: &HplConfig,
    topo: Topology,
    model: NetModel,
    source: Rc<dyn DgemmSource>,
    ranks_per_node: usize,
) -> HplResult {
    run_once_traced(cfg, topo, model, source, ranks_per_node, None)
}

/// [`run_once`] with an optional schedule tracer attached to the world
/// — the capture side of `coordinator::backend::skeleton`.
pub(crate) fn run_once_traced(
    cfg: &HplConfig,
    topo: Topology,
    model: NetModel,
    source: Rc<dyn DgemmSource>,
    ranks_per_node: usize,
    tracer: Option<Rc<crate::mpi::Tracer>>,
) -> HplResult {
    cfg.validate().expect("invalid HPL config");
    let sim = Sim::with_capacity(cfg.nranks());
    let net = Network::new(sim.clone(), topo, model);
    let world = World::new(sim.clone(), net, cfg.nranks(), ranks_per_node);
    world.set_tracer(tracer);
    let cfg_rc = Rc::new(cfg.clone());
    let models = KernelModels::default_aux(source);
    for r in 0..cfg.nranks() {
        sim.spawn(rank_main(world.ctx(r), cfg_rc.clone(), models.clone()));
    }
    let (seconds, stats) = sim.run_with_stats();
    HplResult {
        seconds,
        gflops: cfg.flops() / seconds / 1e9,
        comm: world.stats(),
        events: stats.events,
        dgemm_calls: 0,
    }
}

/// Production pipeline: record the (data-independent) dgemm schedule,
/// evaluate every duration in batch through the XLA artifact, then
/// replay. `seed` drives the half-normal draws.
///
/// This is the single-point form of the pipeline; campaigns batch the
/// evaluation *across* points instead (one
/// `Artifacts::evaluate_batch` invocation per wave — see
/// `coordinator::backend::artifact`). Both forms share the same
/// request/replay surfaces, so they evaluate identically.
pub fn simulate_with_artifacts(
    cfg: &HplConfig,
    topo: &Topology,
    model: &NetModel,
    dgemm: &DgemmModel,
    arts: &Artifacts,
    ranks_per_node: usize,
    seed: u64,
) -> crate::runtime::Result<HplResult> {
    // Pass 1: record shapes (mean-only timings; the schedule is
    // data-independent so any timing works).
    let recorder = Recorder::new(dgemm.clone(), cfg.nranks());
    run_once(cfg, topo.clone(), model.clone(), recorder.clone(), ranks_per_node);
    let total = recorder.total();

    // Batched stochastic evaluation through PJRT: the flattened shapes,
    // the per-(rank, epoch) episodic noise draws, and the coefficient
    // table travel as one request.
    let request = recorder.request(seed);
    let durations = arts.evaluate_batch(std::slice::from_ref(&request))?;

    // Pass 2: replay with pooled durations (the schedule moves out of
    // the spent recorder instead of being cloned).
    let pool = PoolSource::from_calls(recorder.calls.take(), &durations[0]);
    let mut res = run_once(cfg, topo.clone(), model.clone(), pool, ranks_per_node);
    res.dgemm_calls = total;
    Ok(res)
}

/// Pure-Rust convenience used by tests and quick sweeps: sample the
/// model directly (no artifacts required).
pub fn simulate_direct(
    cfg: &HplConfig,
    topo: &Topology,
    model: &NetModel,
    dgemm: &DgemmModel,
    ranks_per_node: usize,
    seed: u64,
) -> HplResult {
    let source = crate::blas::DirectSource::new(dgemm.clone(), cfg.nranks(), seed);
    run_once(cfg, topo.clone(), model.clone(), source, ranks_per_node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{DirectSource, NodeCoef};
    use crate::hpl::config::{Bcast, Rfact, SwapAlg};

    fn small_cfg(n: usize, p: usize, q: usize) -> HplConfig {
        HplConfig {
            n,
            nb: 32,
            p,
            q,
            depth: 0,
            bcast: Bcast::Ring,
            swap: SwapAlg::BinExch,
            swap_threshold: 64,
            rfact: Rfact::Crout,
            nbmin: 8,
        }
    }

    fn dgemm_model() -> DgemmModel {
        DgemmModel::homogeneous(NodeCoef {
            mu: [1e-11, 0.0, 0.0, 0.0, 5e-7],
            sigma: [0.0; 5],
        })
    }

    fn run(cfg: &HplConfig) -> HplResult {
        let topo = Topology::star(cfg.nranks(), 12.5e9, 50e9);
        let src = DirectSource::deterministic(dgemm_model(), cfg.nranks());
        run_once(cfg, topo, NetModel::ideal(), src, 1)
    }

    #[test]
    fn tiny_run_completes_and_times_are_sane() {
        let cfg = small_cfg(256, 2, 2);
        let r = run(&cfg);
        assert!(r.seconds > 0.0 && r.seconds < 10.0, "{}", r.seconds);
        assert!(r.gflops > 0.0);
        assert!(r.comm.messages > 0);
    }

    #[test]
    fn all_bcasts_complete() {
        for bcast in Bcast::ALL {
            let mut cfg = small_cfg(192, 2, 3);
            cfg.bcast = bcast;
            let r = run(&cfg);
            assert!(r.seconds > 0.0, "{bcast:?}");
        }
    }

    #[test]
    fn all_swaps_and_rfacts_complete() {
        for swap in SwapAlg::ALL {
            for rfact in Rfact::ALL {
                let mut cfg = small_cfg(160, 2, 2);
                cfg.swap = swap;
                cfg.rfact = rfact;
                let r = run(&cfg);
                assert!(r.seconds > 0.0, "{swap:?} {rfact:?}");
            }
        }
    }

    #[test]
    fn depth1_completes_and_is_not_slower_for_larger_n() {
        for &(p, q) in &[(2, 2), (2, 3), (1, 4)] {
            let mut c0 = small_cfg(512, p, q);
            let mut c1 = c0.clone();
            c1.depth = 1;
            let r0 = run(&c0);
            let r1 = run(&c1);
            assert!(r1.seconds > 0.0);
            // Look-ahead should never be catastrophically worse.
            assert!(
                r1.seconds < 1.5 * r0.seconds,
                "depth1 {} vs depth0 {} at {p}x{q}",
                r1.seconds,
                r0.seconds
            );
            c0.n = 0; // silence unused-mut lints via reuse
            let _ = c0;
        }
    }

    #[test]
    fn deterministic_replay_same_seed() {
        let cfg = small_cfg(256, 2, 2);
        let topo = Topology::star(4, 12.5e9, 50e9);
        let m = dgemm_model();
        let a = simulate_direct(&cfg, &topo, &NetModel::ideal(), &m, 1, 7);
        let b = simulate_direct(&cfg, &topo, &NetModel::ideal(), &m, 1, 7);
        assert_eq!(a.seconds, b.seconds);
    }

    #[test]
    fn stochastic_model_slower_than_deterministic_mean() {
        // Half-normal noise only adds time on the critical path.
        let mut cfg = small_cfg(384, 2, 2);
        cfg.depth = 0;
        let topo = Topology::star(4, 12.5e9, 50e9);
        let det = dgemm_model();
        let mut sto = det.clone();
        for c in sto.nodes.iter_mut() {
            c.sigma = [3e-13, 0.0, 0.0, 0.0, 0.0];
        }
        let r_det = simulate_direct(&cfg, &topo, &NetModel::ideal(), &det, 1, 1);
        let r_sto = simulate_direct(&cfg, &topo, &NetModel::ideal(), &sto, 1, 1);
        assert!(
            r_sto.seconds > r_det.seconds,
            "stochastic {} should exceed deterministic {}",
            r_sto.seconds,
            r_det.seconds
        );
    }

    #[test]
    fn elongated_geometries_move_more_data() {
        // Total communication volume ∝ (P+Q)·N²: 1x8 ≫ 2x4. (The *time*
        // contrast needs a calibrated network and larger N; that is
        // exercised by the Fig. 7 experiment.)
        let r_square = run(&small_cfg(512, 2, 4));
        let r_flat = run(&small_cfg(512, 1, 8));
        assert!(
            r_flat.comm.bytes > r_square.comm.bytes,
            "1x8 {} bytes vs 2x4 {} bytes",
            r_flat.comm.bytes,
            r_square.comm.bytes
        );
    }

    #[test]
    fn record_replay_roundtrip_with_direct_pool() {
        // Record, evaluate durations in Rust (no artifacts), replay:
        // the replay must complete and visit the same schedule.
        let cfg = small_cfg(256, 2, 2);
        let topo = Topology::star(4, 12.5e9, 50e9);
        let rec = Recorder::new(dgemm_model(), cfg.nranks());
        run_once(&cfg, topo.clone(), NetModel::ideal(), rec.clone(), 1);
        let total = rec.total();
        assert!(total > 0);
        let (mnk, _idx, _) = rec.flatten();
        let durs: Vec<f32> = mnk
            .iter()
            .map(|p| (1e-11 * p[0] as f64 * p[1] as f64 * p[2] as f64 + 5e-7) as f32)
            .collect();
        let pool = PoolSource::new(&rec, &durs);
        let r = run_once(&cfg, topo, NetModel::ideal(), pool, 1);
        assert!(r.seconds > 0.0);
    }
}
