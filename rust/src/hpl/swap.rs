//! Row-swap + U broadcast along a process column (HPL's pdlaswp).
//!
//! During the trailing update, the pivot rows must be swapped into place
//! and the U stripe (jb x nq_local) replicated across the P process rows
//! of each column. HPL offers binary-exchange (log2 P rounds of full-size
//! exchanges) and spread-roll (scatter + ring roll: more messages, less
//! volume per link), plus a threshold mix.

use super::config::SwapAlg;
use crate::mpi::Ctx;

/// Effective algorithm after threshold resolution.
pub fn resolve(alg: SwapAlg, jb: usize, threshold: usize) -> SwapAlg {
    match alg {
        SwapAlg::Mix => {
            if jb <= threshold {
                SwapAlg::BinExch
            } else {
                SwapAlg::SpreadRoll
            }
        }
        other => other,
    }
}

/// Perform the swap-broadcast for `bytes = jb * nq_local * 8` within the
/// column group. `group` is the P ranks of my process column, `me_pos`
/// my row index.
pub async fn swap_bcast(
    ctx: &Ctx,
    alg: SwapAlg,
    jb: usize,
    threshold: usize,
    group: &[usize],
    me_pos: usize,
    tag: u64,
    bytes: f64,
) {
    let p = group.len();
    if p <= 1 || bytes <= 0.0 {
        return;
    }
    match resolve(alg, jb, threshold) {
        SwapAlg::BinExch => {
            // ceil(log2 P) rounds of pairwise exchanges of the full
            // stripe (binary-exchange tree).
            let rounds = usize::BITS as usize - (p - 1).leading_zeros() as usize;
            for r in 0..rounds {
                let partner = me_pos ^ (1 << r);
                if partner >= p {
                    continue;
                }
                let t = tag + r as u64;
                let h = ctx.isend(group[partner], t, bytes);
                ctx.recv(Some(group[partner]), t).await;
                h.await;
            }
        }
        SwapAlg::SpreadRoll => {
            // Scatter + ring roll: P-1 rounds of bytes/P, all ranks
            // sending concurrently (higher parallelism, §2 SWAP).
            let piece = bytes / p as f64;
            for r in 0..p - 1 {
                let next = group[(me_pos + 1) % p];
                let prev = group[(me_pos + p - 1) % p];
                let t = tag + r as u64;
                let h = ctx.isend(next, t, piece);
                ctx.recv(Some(prev), t).await;
                h.await;
            }
        }
        SwapAlg::Mix => unreachable!("resolved above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Sim;
    use crate::mpi::World;
    use crate::network::{NetModel, Network, Topology};
    use std::cell::Cell;
    use std::rc::Rc;

    #[test]
    fn mix_threshold_resolution() {
        assert_eq!(resolve(SwapAlg::Mix, 64, 64), SwapAlg::BinExch);
        assert_eq!(resolve(SwapAlg::Mix, 128, 64), SwapAlg::SpreadRoll);
        assert_eq!(resolve(SwapAlg::BinExch, 999, 64), SwapAlg::BinExch);
        assert_eq!(resolve(SwapAlg::SpreadRoll, 1, 64), SwapAlg::SpreadRoll);
    }

    fn run_swap(p: usize, alg: SwapAlg) -> f64 {
        let sim = Sim::new();
        let topo = Topology::star(p, 1e9, 4e9);
        let net = Network::new(sim.clone(), topo, NetModel::ideal());
        let w = World::new(sim.clone(), net, p, 1);
        let group: Vec<usize> = (0..p).collect();
        let done = Rc::new(Cell::new(0usize));
        for me in 0..p {
            let ctx = w.ctx(me);
            let g = group.clone();
            let d = done.clone();
            sim.spawn(async move {
                swap_bcast(&ctx, alg, 128, 64, &g, me, 1000, 1e7).await;
                d.set(d.get() + 1);
            });
        }
        let t = sim.run();
        assert_eq!(done.get(), p);
        t
    }

    #[test]
    fn both_algorithms_complete_for_various_p() {
        for p in [2, 3, 4, 5, 8, 11] {
            run_swap(p, SwapAlg::BinExch);
            run_swap(p, SwapAlg::SpreadRoll);
        }
    }

    #[test]
    fn spread_roll_moves_less_volume_per_rank_for_large_p() {
        // For P=8 with equal per-message sizes, binexch sends 3 full
        // stripes per rank vs spread-roll's 7 * (1/8): spread-roll
        // should finish faster on a contention-free star.
        let t_bin = run_swap(8, SwapAlg::BinExch);
        let t_roll = run_swap(8, SwapAlg::SpreadRoll);
        assert!(
            t_roll < t_bin,
            "spread-roll {t_roll} should beat binexch {t_bin} at P=8"
        );
    }

    #[test]
    fn single_rank_is_noop() {
        run_swap(1, SwapAlg::BinExch);
    }
}
