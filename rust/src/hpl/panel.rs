//! Recursive panel factorization (HPL's pdrpan{L,C,R} / pdpan*).
//!
//! The P ranks of the panel-owning process column factor the mp x jb
//! panel together: pivot search needs one max-loc all-reduce per column
//! along the column group, and the local arithmetic is rank-1 updates
//! plus recursive trailing updates whose shape depends on the RFACT
//! variant.
//!
//! Event-count note: HPL performs one all-reduce per *column*; we
//! aggregate them per recursion *leaf* (NBMIN columns) with the summed
//! byte volume. This preserves communication volume and the P-scaling
//! of the critical path while keeping simulated event counts tractable
//! (see DESIGN.md §Substitutions).

use std::future::Future;
use std::pin::Pin;

use super::config::Rfact;
use super::driver::compute_dgemm;
use crate::blas::KernelModels;
use crate::mpi::{collectives, Ctx};

/// Panel-factorization context for one rank.
pub struct PanelFact<'a> {
    pub ctx: &'a Ctx,
    pub models: &'a KernelModels,
    /// Ranks of the panel-owning process column (P entries, by row).
    pub group: &'a [usize],
    /// My row position within `group`.
    pub me_pos: usize,
    /// Node hosting this rank.
    pub node: usize,
    pub nbmin: usize,
    pub rfact: Rfact,
    /// Tag base for this panel's all-reduces (kind FACT).
    pub tag_base: u64,
    /// All-reduce sequence counter (each uses two tags).
    seq: u64,
    /// Total panel width (for pivot-row byte accounting).
    jb_total: usize,
    /// HPL iteration this panel belongs to (noise epoch).
    epoch: usize,
}

impl<'a> PanelFact<'a> {
    pub fn new(
        ctx: &'a Ctx,
        models: &'a KernelModels,
        group: &'a [usize],
        me_pos: usize,
        node: usize,
        nbmin: usize,
        rfact: Rfact,
        tag_base: u64,
        jb_total: usize,
        epoch: usize,
    ) -> Self {
        PanelFact {
            ctx,
            models,
            group,
            me_pos,
            node,
            nbmin,
            rfact,
            tag_base,
            seq: 0,
            jb_total,
            epoch,
        }
    }

    /// Factor an `mp x jb` local panel slice.
    pub async fn run(&mut self, mp: usize, jb: usize) {
        // Copy the panel into workspace (HPL_dlatcpy).
        let copy = self.models.dlatcpy.of((mp * jb) as f64);
        self.ctx.compute(copy).await;
        self.rec(mp, jb).await;
    }

    /// Leaf factorization of `cols` columns (aggregated pfact).
    async fn leaf(&mut self, mp: usize, cols: usize) {
        let m = self.models;
        // Per column: idamax over the local rows + a daxpy-scale pass;
        // aggregated over the leaf.
        let search = (m.idamax.of(mp as f64) + m.daxpy.of(mp as f64)) * cols as f64;
        self.ctx.compute(search).await;
        // Pivot max-loc all-reduce along the process column: one per
        // column in HPL, aggregated per leaf here. Each carries the
        // candidate row of the whole panel width plus indices.
        let bytes = cols as f64 * (4.0 + 2.0 * self.jb_total as f64) * 8.0;
        let tag = self.tag_base + 2 * self.seq;
        self.seq += 1;
        collectives::allreduce_tree(self.ctx, self.group, self.me_pos, tag, bytes).await;
        // Rank-1 update cascade of the leaf ≈ one (mp, cols, cols) GEMM.
        if mp > 0 && cols > 0 {
            compute_dgemm(self.ctx, m, self.node, self.epoch, mp, cols, cols).await;
        }
    }

    /// Recursive factorization; shapes follow the RFACT variant.
    fn rec<'s>(
        &'s mut self,
        mp: usize,
        cols: usize,
    ) -> Pin<Box<dyn Future<Output = ()> + 's>> {
        Box::pin(async move {
            if cols <= self.nbmin {
                self.leaf(mp, cols).await;
                return;
            }
            let n1 = cols / 2;
            let n2 = cols - n1;
            let m = self.models;
            match self.rfact {
                Rfact::Right => {
                    // Factor left, update the trailing part of the
                    // panel, factor right.
                    self.rec(mp, n1).await;
                    self.ctx.compute(m.dtrsm.of((n1 * n1 * n2) as f64)).await;
                    let rows = mp.saturating_sub(n1);
                    if rows > 0 {
                        compute_dgemm(self.ctx, m, self.node, self.epoch, rows, n2, n1).await;
                    }
                    self.rec(mp, n2).await;
                }
                Rfact::Crout => {
                    // Crout: updates deferred — the right part is
                    // updated just before its factorization with the
                    // accumulated left factors.
                    self.rec(mp, n1).await;
                    let rows = mp.saturating_sub(n1);
                    if rows > 0 {
                        compute_dgemm(self.ctx, m, self.node, self.epoch, rows, n2, n1).await;
                    }
                    self.ctx.compute(m.dtrsm.of((n1 * n1 * n2) as f64)).await;
                    self.rec(mp, n2).await;
                }
                Rfact::Left => {
                    // Left-looking: update spans all local rows.
                    self.rec(mp, n1).await;
                    self.ctx.compute(m.dtrsm.of((n1 * n1 * n2) as f64)).await;
                    if mp > 0 {
                        compute_dgemm(self.ctx, m, self.node, self.epoch, mp, n2, n1).await;
                    }
                    self.rec(mp, n2).await;
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{DgemmModel, DirectSource, NodeCoef};
    use crate::engine::Sim;
    use crate::mpi::World;
    use crate::network::{NetModel, Network, Topology};
    use std::cell::Cell;
    use std::rc::Rc;

    fn models(nranks: usize) -> KernelModels {
        let dm = DgemmModel::homogeneous(NodeCoef::naive(1e-11));
        KernelModels::default_aux(DirectSource::deterministic(dm, nranks))
    }

    fn run_fact(p: usize, mp: usize, jb: usize, rfact: Rfact) -> f64 {
        let sim = Sim::new();
        let topo = Topology::star(p, 1e9, 4e9);
        let net = Network::new(sim.clone(), topo, NetModel::ideal());
        let w = World::new(sim.clone(), net, p, 1);
        let km = models(p);
        let group: Vec<usize> = (0..p).collect();
        let done = Rc::new(Cell::new(0usize));
        for me in 0..p {
            let ctx = w.ctx(me);
            let g = group.clone();
            let km = km.clone();
            let d = done.clone();
            sim.spawn(async move {
                let mut pf =
                    PanelFact::new(&ctx, &km, &g, me, me, 8, rfact, 1 << 16, jb, 0);
                pf.run(mp, jb).await;
                d.set(d.get() + 1);
            });
        }
        let t = sim.run();
        assert_eq!(done.get(), p);
        t
    }

    #[test]
    fn completes_for_all_variants_and_sizes() {
        for rfact in Rfact::ALL {
            for (p, jb) in [(1, 32), (2, 64), (4, 128), (3, 96)] {
                let t = run_fact(p, 1000, jb, rfact);
                assert!(t > 0.0);
            }
        }
    }

    #[test]
    fn wider_panel_takes_longer() {
        let t64 = run_fact(4, 2000, 64, Rfact::Crout);
        let t256 = run_fact(4, 2000, 256, Rfact::Crout);
        assert!(t256 > t64, "{t256} vs {t64}");
    }

    #[test]
    fn more_rows_take_longer() {
        let a = run_fact(2, 500, 128, Rfact::Right);
        let b = run_fact(2, 5000, 128, Rfact::Right);
        assert!(b > a);
    }

    #[test]
    fn variants_cost_similar_but_not_identical_schedules() {
        // The paper found RFACT has nearly no influence; our emulation
        // should produce close (within 50%) but distinct timings.
        let l = run_fact(4, 4000, 128, Rfact::Left);
        let c = run_fact(4, 4000, 128, Rfact::Crout);
        let r = run_fact(4, 4000, 128, Rfact::Right);
        for (a, b) in [(l, c), (c, r), (l, r)] {
            assert!(a / b < 1.5 && b / a < 1.5, "{a} vs {b}");
        }
    }
}
