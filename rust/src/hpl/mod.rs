//! The HPL (High-Performance Linpack) emulation.
//!
//! A faithful skeleton of HPL 2.2's `pdgesv`: right-looking LU with row
//! partial pivoting on a P x Q block-cyclic grid, recursive panel
//! factorization, six panel-broadcast algorithms, three row-swap
//! algorithms and look-ahead — with every BLAS call replaced by the
//! paper's statistical performance models (the `blas` module), exactly
//! like the paper's macro-substituted HPL running over SMPI (§3.2).

pub mod bcast;
pub mod config;
pub mod driver;
pub mod grid;
pub mod panel;
pub mod swap;

pub use bcast::BcastOp;
pub use config::{Bcast, HplConfig, Rfact, SwapAlg};
pub use driver::{run_once, simulate_direct, simulate_with_artifacts, HplResult};
pub use grid::{local_count, Grid};
