//! Process grid and two-dimensional block-cyclic distribution math.
//!
//! HPL distributes the N x N matrix over a P x Q grid in NB x NB blocks:
//! block (I, J) lives on process (I mod P, J mod Q). Ranks are laid out
//! row-major: `rank = row * Q + col` (HPL's default ordering).

/// A P x Q process grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pub p: usize,
    pub q: usize,
}

impl Grid {
    pub fn new(p: usize, q: usize) -> Grid {
        assert!(p >= 1 && q >= 1);
        Grid { p, q }
    }

    pub fn nranks(&self) -> usize {
        self.p * self.q
    }

    pub fn rank(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.p && col < self.q);
        row * self.q + col
    }

    pub fn row_of(&self, rank: usize) -> usize {
        rank / self.q
    }

    pub fn col_of(&self, rank: usize) -> usize {
        rank % self.q
    }

    /// Ranks of one process row (Q entries, by column).
    pub fn row_group(&self, row: usize) -> Vec<usize> {
        (0..self.q).map(|c| self.rank(row, c)).collect()
    }

    /// Ranks of one process column (P entries, by row).
    pub fn col_group(&self, col: usize) -> Vec<usize> {
        (0..self.p).map(|r| self.rank(r, col)).collect()
    }
}

/// Number of blocks `b` in `[first, last)` with `b % nprocs == proc`.
pub fn count_blocks(first: usize, last: usize, proc: usize, nprocs: usize) -> usize {
    debug_assert!(proc < nprocs);
    if last <= first {
        return 0;
    }
    let offset = (proc + nprocs - first % nprocs) % nprocs;
    let b0 = first + offset;
    if b0 >= last {
        0
    } else {
        (last - 1 - b0) / nprocs + 1
    }
}

/// Number of matrix rows (or columns) owned by `proc` among the global
/// index range `[first_block * nb, n)` of an N-row matrix distributed in
/// NB-row blocks over `nprocs` processes.
pub fn local_count(n: usize, nb: usize, first_block: usize, proc: usize, nprocs: usize) -> usize {
    let total = n.div_ceil(nb);
    if first_block >= total {
        return 0;
    }
    let blocks = count_blocks(first_block, total, proc, nprocs);
    let mut rows = blocks * nb;
    // The final block may be partial.
    let last = total - 1;
    if last >= first_block && last % nprocs == proc {
        rows = rows - nb + (n - last * nb);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_layout_row_major() {
        let g = Grid::new(2, 3);
        assert_eq!(g.rank(0, 0), 0);
        assert_eq!(g.rank(0, 2), 2);
        assert_eq!(g.rank(1, 0), 3);
        assert_eq!(g.row_of(4), 1);
        assert_eq!(g.col_of(4), 1);
        assert_eq!(g.row_group(1), vec![3, 4, 5]);
        assert_eq!(g.col_group(2), vec![2, 5]);
    }

    #[test]
    fn count_blocks_basic() {
        // Blocks 0..10 over 3 procs: proc 0 owns 0,3,6,9.
        assert_eq!(count_blocks(0, 10, 0, 3), 4);
        assert_eq!(count_blocks(0, 10, 1, 3), 3);
        assert_eq!(count_blocks(0, 10, 2, 3), 3);
        // Starting mid-way.
        assert_eq!(count_blocks(4, 10, 0, 3), 2); // 6, 9
        assert_eq!(count_blocks(4, 10, 1, 3), 2); // 4, 7
        assert_eq!(count_blocks(10, 10, 0, 3), 0);
        assert_eq!(count_blocks(9, 10, 0, 3), 1);
    }

    #[test]
    fn count_blocks_exhaustive_small() {
        for nprocs in 1..6 {
            for first in 0..8 {
                for last in first..12 {
                    for proc in 0..nprocs {
                        let brute =
                            (first..last).filter(|b| b % nprocs == proc).count();
                        assert_eq!(
                            count_blocks(first, last, proc, nprocs),
                            brute,
                            "f={first} l={last} p={proc}/{nprocs}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn local_count_partitions_exactly() {
        // Property: sum over procs == remaining rows, for many shapes.
        for &(n, nb, nprocs) in &[
            (1000usize, 128usize, 4usize),
            (999, 100, 3),
            (50, 64, 4),
            (1, 1, 1),
            (12345, 97, 7),
        ] {
            let total = n.div_ceil(nb);
            for first in 0..total.min(6) {
                let sum: usize =
                    (0..nprocs).map(|p| local_count(n, nb, first, p, nprocs)).sum();
                assert_eq!(sum, n - first * nb, "n={n} nb={nb} first={first}");
            }
        }
    }

    #[test]
    fn local_count_handles_partial_last_block() {
        // n=250, nb=100: blocks 0(100), 1(100), 2(50) over 2 procs.
        assert_eq!(local_count(250, 100, 0, 0, 2), 150); // blocks 0, 2
        assert_eq!(local_count(250, 100, 0, 1, 2), 100); // block 1
        assert_eq!(local_count(250, 100, 2, 0, 2), 50);
        assert_eq!(local_count(250, 100, 2, 1, 2), 0);
        assert_eq!(local_count(250, 100, 3, 0, 2), 0);
    }

    #[test]
    fn local_count_randomized_against_brute_force() {
        let mut rng = crate::stats::Rng::new(7);
        for _ in 0..200 {
            let n = 1 + rng.below(5000);
            let nb = 1 + rng.below(300);
            let nprocs = 1 + rng.below(9);
            let total = n.div_ceil(nb);
            let first = rng.below(total + 1);
            let proc = rng.below(nprocs);
            let mut brute = 0usize;
            for b in first..total {
                if b % nprocs == proc {
                    brute += if b == total - 1 { n - b * nb } else { nb };
                }
            }
            assert_eq!(local_count(n, nb, first, proc, nprocs), brute);
        }
    }
}
