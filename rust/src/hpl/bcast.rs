//! The six HPL panel-broadcast algorithms along a process row.
//!
//! Ring variants make progress through `MPI_Iprobe` polled from inside
//! the trailing update (partial communication/computation overlap); the
//! long (spread-and-roll) variants are blocking, as in HPL 2.1/2.2 where
//! their Iprobe capability is disabled (§2 of the paper).

use super::config::Bcast;
use crate::mpi::trace::{BcastDesc, Op};
use crate::mpi::{Ctx, SendHandle, TraceSuppress};

/// Tag layout: see [`super::driver::tag`].
fn fwd_tag(base: u64) -> u64 {
    base
}

/// Communication plan of a ring-family broadcast, in root-relative
/// positions `d in 1..q` (d=0 is the root).
///
/// Returns, for a non-root `d`: `(source d, forward targets)`.
pub fn ring_plan(alg: Bcast, q: usize, d: usize) -> (usize, Vec<usize>) {
    debug_assert!(d >= 1 && d < q);
    match alg {
        Bcast::Ring => {
            let src = d - 1;
            let fwd = if d + 1 < q { vec![d + 1] } else { vec![] };
            (src, fwd)
        }
        Bcast::RingM => {
            if q <= 2 {
                return ring_plan(Bcast::Ring, q, d);
            }
            // d=1: served directly by root, never forwards (it becomes
            // the next root). The chain is root -> 2 -> 3 -> ... -> q-1.
            match d {
                1 => (0, vec![]),
                2 => (0, if d + 1 < q { vec![d + 1] } else { vec![] }),
                _ => (d - 1, if d + 1 < q { vec![d + 1] } else { vec![] }),
            }
        }
        Bcast::TwoRing => {
            // Two chains: root -> 1 -> 2 -> ... -> h and
            //             root -> h+1 -> ... -> q-1.
            let h = (q - 1).div_ceil(2);
            if d <= h {
                let src = d - 1; // d=1 gets it from the root
                let fwd = if d + 1 <= h { vec![d + 1] } else { vec![] };
                (src, fwd)
            } else {
                let src = if d == h + 1 { 0 } else { d - 1 };
                let fwd = if d + 1 < q { vec![d + 1] } else { vec![] };
                (src, fwd)
            }
        }
        Bcast::TwoRingM => {
            if q <= 3 {
                return ring_plan(Bcast::TwoRing, q, d);
            }
            // d=1 direct from root, no forward; two chains over 2..q-1.
            if d == 1 {
                return (0, vec![]);
            }
            let rest = q - 2; // members 2..q-1
            let h = 1 + rest.div_ceil(2); // last d of chain 1
            if d <= h {
                let src = if d == 2 { 0 } else { d - 1 };
                let fwd = if d + 1 <= h { vec![d + 1] } else { vec![] };
                (src, fwd)
            } else {
                let src = if d == h + 1 { 0 } else { d - 1 };
                let fwd = if d + 1 < q { vec![d + 1] } else { vec![] };
                (src, fwd)
            }
        }
        Bcast::Long | Bcast::LongM => unreachable!("long variants use spread-roll"),
    }
}

/// Root's direct targets for the ring-family algorithms.
pub fn root_plan(alg: Bcast, q: usize) -> Vec<usize> {
    if q <= 1 {
        return vec![];
    }
    match alg {
        Bcast::Ring => vec![1],
        Bcast::RingM => {
            if q <= 2 {
                vec![1]
            } else {
                vec![1, 2]
            }
        }
        Bcast::TwoRing => {
            let h = (q - 1).div_ceil(2);
            if h + 1 < q {
                vec![1, h + 1]
            } else {
                vec![1]
            }
        }
        Bcast::TwoRingM => {
            if q <= 3 {
                return root_plan(Bcast::TwoRing, q);
            }
            let rest = q - 2;
            let h = 1 + rest.div_ceil(2);
            if h + 1 < q {
                vec![1, 2, h + 1]
            } else {
                vec![1, 2]
            }
        }
        Bcast::Long | Bcast::LongM => vec![],
    }
}

/// One panel broadcast in flight on one rank.
pub struct BcastOp {
    pub alg: Bcast,
    /// Row group (ranks of my process row, by column).
    group: Vec<usize>,
    me_pos: usize,
    root_pos: usize,
    bytes: f64,
    tag: u64,
    done: bool,
    handles: Vec<SendHandle>,
    /// Skeleton-trace descriptor id, registered on first marker.
    trace_id: Option<usize>,
}

/// Which lifecycle marker a call site emits when tracing.
enum Marker {
    Start,
    Poll,
    Finish,
}

impl BcastOp {
    pub fn new(
        alg: Bcast,
        group: Vec<usize>,
        me_pos: usize,
        root_pos: usize,
        bytes: f64,
        tag: u64,
    ) -> BcastOp {
        BcastOp {
            alg,
            group,
            me_pos,
            root_pos,
            bytes,
            tag,
            done: false,
            handles: vec![],
            trace_id: None,
        }
    }

    fn q(&self) -> usize {
        self.group.len()
    }

    fn d(&self) -> usize {
        (self.me_pos + self.q() - self.root_pos) % self.q()
    }

    fn abs(&self, d: usize) -> usize {
        self.group[(d + self.root_pos) % self.q()]
    }

    /// Emit the lifecycle marker for one `start`/`poll`/`finish` call
    /// (registering this rank's descriptor on first use), and suppress
    /// the body's primitives until the guard drops: which calls do
    /// work is timing-dependent, so the replay VM re-enacts the
    /// broadcast from the descriptor rather than from a literal trace.
    /// No-op without a tracer.
    fn trace_marker(&mut self, ctx: &Ctx, marker: Marker) -> Option<TraceSuppress> {
        if !ctx.tracing() {
            return None;
        }
        if self.trace_id.is_none() {
            let d = self.d();
            let desc = if d == 0 {
                BcastDesc {
                    is_root: true,
                    src_abs: self.abs(0),
                    fwd_abs: vec![],
                    root_targets_abs: root_plan(self.alg, self.q())
                        .into_iter()
                        .map(|x| self.abs(x))
                        .collect(),
                    tag: fwd_tag(self.tag),
                    bytes: self.bytes,
                }
            } else {
                let (src_d, fwd) = ring_plan(self.alg, self.q(), d);
                BcastDesc {
                    is_root: false,
                    src_abs: self.abs(src_d),
                    fwd_abs: fwd.into_iter().map(|x| self.abs(x)).collect(),
                    root_targets_abs: vec![],
                    tag: fwd_tag(self.tag),
                    bytes: self.bytes,
                }
            };
            self.trace_id = Some(ctx.trace_desc(desc));
        }
        let id = self.trace_id.unwrap();
        ctx.trace_log(|| match marker {
            Marker::Start => Op::BcastStart { desc: id },
            Marker::Poll => Op::BcastPoll { desc: id },
            Marker::Finish => Op::BcastFinish { desc: id },
        });
        ctx.trace_suppress()
    }

    /// Kick off the broadcast. Roots of ring variants launch their
    /// sends in the background; everything else is lazy.
    pub fn start(&mut self, ctx: &Ctx) {
        if self.q() <= 1 {
            self.done = true;
            return;
        }
        if !self.alg.overlaps() {
            return;
        }
        let _g = self.trace_marker(ctx, Marker::Start);
        if self.d() == 0 {
            for dst_d in root_plan(self.alg, self.q()) {
                let dst = self.abs(dst_d);
                self.handles.push(ctx.isend(dst, fwd_tag(self.tag), self.bytes));
            }
            self.done = true; // root has the panel by definition
        }
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// One polling step (called between update chunks). Returns whether
    /// the panel has arrived locally. Long variants make no progress
    /// here (no Iprobe in HPL 2.1/2.2).
    pub async fn poll(&mut self, ctx: &Ctx) -> bool {
        let _g = if self.alg.overlaps() && self.q() > 1 {
            self.trace_marker(ctx, Marker::Poll)
        } else {
            None
        };
        if self.done {
            return true;
        }
        if !self.alg.overlaps() {
            return false;
        }
        let (src_d, fwd) = ring_plan(self.alg, self.q(), self.d());
        let src = self.abs(src_d);
        if ctx.iprobe(Some(src), fwd_tag(self.tag)).await {
            ctx.recv(Some(src), fwd_tag(self.tag)).await;
            for f in fwd {
                let dst = self.abs(f);
                self.handles.push(ctx.isend(dst, fwd_tag(self.tag), self.bytes));
            }
            self.done = true;
        }
        self.done
    }

    /// Block until the panel has arrived (and, for the root, until its
    /// sends have been pushed). With nothing left to overlap, HPL's
    /// Iprobe busy-wait is equivalent to a blocking receive (the rank
    /// burns cycles that affect nothing else), so ring variants recv
    /// directly here; long variants run the whole spread-and-roll.
    pub async fn finish(&mut self, ctx: &Ctx) {
        if self.q() <= 1 {
            self.done = true;
            return;
        }
        let _g = if self.alg.overlaps() {
            self.trace_marker(ctx, Marker::Finish)
        } else {
            None
        };
        if !self.done {
            if self.alg.overlaps() {
                let (src_d, fwd) = ring_plan(self.alg, self.q(), self.d());
                let src = self.abs(src_d);
                ctx.recv(Some(src), fwd_tag(self.tag)).await;
                for f in fwd {
                    let dst = self.abs(f);
                    self.handles.push(ctx.isend(dst, fwd_tag(self.tag), self.bytes));
                }
                self.done = true;
            } else {
                self.run_long(ctx).await;
                self.done = true;
            }
        }
        for h in self.handles.drain(..) {
            h.await;
        }
    }

    /// Spread-and-roll (long / longM).
    async fn run_long(&mut self, ctx: &Ctx) {
        let q = self.q();
        let d = self.d();
        let modified = self.alg == Bcast::LongM && q > 2;
        if modified {
            // The next root receives the full panel directly and does
            // not take part in the roll.
            if d == 0 {
                ctx.send(self.abs(1), self.tag, self.bytes).await;
            } else if d == 1 {
                ctx.recv(Some(self.abs(0)), self.tag).await;
                return;
            }
        }
        // Participants (root-relative positions).
        let first = if modified { 2 } else { 1 };
        let mut parts = vec![0usize];
        parts.extend(first..q);
        let np = parts.len();
        if np <= 1 {
            return;
        }
        let my_i = parts.iter().position(|&x| x == d).expect("participant");
        let piece = self.bytes / np as f64;
        // Spread: the root scatters distinct pieces.
        if my_i == 0 {
            let mut hs = Vec::new();
            for &pd in &parts[1..] {
                hs.push(ctx.isend(self.abs(pd), self.tag + 1, piece));
            }
            for h in hs {
                h.await;
            }
        } else {
            ctx.recv(Some(self.abs(0)), self.tag + 1).await;
        }
        // Roll: np-1 ring rounds, everyone forwarding concurrently.
        for r in 0..np - 1 {
            let next = self.abs(parts[(my_i + 1) % np]);
            let prev = self.abs(parts[(my_i + np - 1) % np]);
            let t = self.tag + 2 + r as u64;
            let h = ctx.isend(next, t, piece);
            ctx.recv(Some(prev), t).await;
            h.await;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every rank must receive the panel exactly once, and forwards must
    /// be consistent (if a sends to b, then b's source is a).
    fn check_plan(alg: Bcast, q: usize) {
        let mut received = vec![0usize; q]; // times each d receives
        // From the root.
        for dst in root_plan(alg, q) {
            assert!(dst >= 1 && dst < q);
            received[dst] += 1;
        }
        // From forwards.
        for d in 1..q {
            let (_, fwd) = ring_plan(alg, q, d);
            for f in fwd {
                assert!(f >= 1 && f < q, "{alg:?} q={q} d={d} fwd={f}");
                received[f] += 1;
            }
        }
        for d in 1..q {
            assert_eq!(received[d], 1, "{alg:?} q={q}: d={d} received {}", received[d]);
        }
        // Source consistency.
        let mut senders: Vec<Vec<usize>> = vec![vec![]; q];
        for dst in root_plan(alg, q) {
            senders[dst].push(0);
        }
        for d in 1..q {
            let (_, fwd) = ring_plan(alg, q, d);
            for f in fwd {
                senders[f].push(d);
            }
        }
        for d in 1..q {
            let (src, _) = ring_plan(alg, q, d);
            assert_eq!(senders[d], vec![src], "{alg:?} q={q} d={d}");
        }
    }

    #[test]
    fn ring_plans_cover_everyone() {
        for alg in [Bcast::Ring, Bcast::RingM, Bcast::TwoRing, Bcast::TwoRingM] {
            for q in 2..40 {
                check_plan(alg, q);
            }
        }
    }

    #[test]
    fn modified_next_root_does_not_forward() {
        for q in 3..20 {
            let (src, fwd) = ring_plan(Bcast::RingM, q, 1);
            assert_eq!(src, 0);
            assert!(fwd.is_empty());
            if q > 3 {
                let (src, fwd) = ring_plan(Bcast::TwoRingM, q, 1);
                assert_eq!(src, 0);
                assert!(fwd.is_empty());
            }
        }
    }

    #[test]
    fn two_ring_has_two_chains() {
        let roots = root_plan(Bcast::TwoRing, 9);
        assert_eq!(roots.len(), 2);
        // Chain heads: 1 and h+1 = 5.
        assert_eq!(roots, vec![1, 5]);
    }

    #[test]
    fn chain_depth_two_ring_shorter_than_ring() {
        // Longest forwarding chain: ring = q-1 hops; 2ring ≈ half.
        fn depth(alg: Bcast, q: usize) -> usize {
            let mut dist = vec![usize::MAX; q];
            dist[0] = 0;
            for dst in root_plan(alg, q) {
                dist[dst] = 1;
            }
            // Relax in topological order (chains are increasing).
            for _ in 0..q {
                for d in 1..q {
                    if dist[d] < usize::MAX {
                        let (_, fwd) = ring_plan(alg, q, d);
                        for f in fwd {
                            dist[f] = dist[f].min(dist[d] + 1);
                        }
                    }
                }
            }
            (1..q).map(|d| dist[d]).max().unwrap_or(0)
        }
        for q in [8, 16, 31] {
            assert!(depth(Bcast::TwoRing, q) < depth(Bcast::Ring, q));
        }
    }
}
