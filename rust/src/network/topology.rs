//! Physical topologies: single-switch star (the Dahu cluster) and a
//! parametric two-level fat-tree (the §5.4 tapering study), both with a
//! per-node loopback tier for intra-node communication.

use crate::stats::json::Json;

/// Link identifier (index into the capacity vector).
pub type LinkId = u32;

/// A physical topology: a set of links plus a routing function.
#[derive(Clone, Debug)]
pub enum Topology {
    /// All nodes attached to one non-blocking switch.
    /// Links: per node `i`: up = 3i, down = 3i+1, loopback = 3i+2.
    Star {
        nodes: usize,
        caps: Vec<f64>,
    },
    /// Two-level fat-tree `(2; down_leaf, leaves; 1, tops; 1, para)`:
    /// `leaves` leaf switches each serving `down_leaf` nodes, `tops` top
    /// switches, `para` parallel up-links per (leaf, top) pair.
    ///
    /// Link layout:
    ///   per node i: up = 3i, down = 3i+1, loopback = 3i+2   (node tier)
    ///   then per (leaf l, top t, k < para): two links (up, down).
    FatTree {
        nodes: usize,
        down_leaf: usize,
        leaves: usize,
        tops: usize,
        para: usize,
        caps: Vec<f64>,
    },
}

impl Topology {
    /// Star topology: `node_bw` on every up/down link, `loop_bw` on the
    /// intra-node loopback.
    pub fn star(nodes: usize, node_bw: f64, loop_bw: f64) -> Topology {
        let mut caps = Vec::with_capacity(3 * nodes);
        for _ in 0..nodes {
            caps.push(node_bw); // up
            caps.push(node_bw); // down
            caps.push(loop_bw); // loopback
        }
        Topology::Star { nodes, caps }
    }

    /// Two-level fat-tree. `tops` is the number of active top-level
    /// switches (the §5.4 experiment deactivates them one by one).
    pub fn fat_tree(
        down_leaf: usize,
        leaves: usize,
        tops: usize,
        para: usize,
        node_bw: f64,
        trunk_bw: f64,
        loop_bw: f64,
    ) -> Topology {
        assert!(tops >= 1 && para >= 1);
        let nodes = down_leaf * leaves;
        let mut caps = Vec::new();
        for _ in 0..nodes {
            caps.push(node_bw);
            caps.push(node_bw);
            caps.push(loop_bw);
        }
        // Trunk links: for each leaf, top, parallel k: up and down.
        for _ in 0..leaves * tops * para {
            caps.push(trunk_bw); // up
            caps.push(trunk_bw); // down
        }
        Topology::FatTree { nodes, down_leaf, leaves, tops, para, caps }
    }

    pub fn nodes(&self) -> usize {
        match self {
            Topology::Star { nodes, .. } => *nodes,
            Topology::FatTree { nodes, .. } => *nodes,
        }
    }

    /// Capacities indexed by `LinkId`.
    pub fn link_capacities(&self) -> &[f64] {
        match self {
            Topology::Star { caps, .. } => caps,
            Topology::FatTree { caps, .. } => caps,
        }
    }

    /// Route between two nodes (list of links crossed, in order).
    pub fn route(&self, src: usize, dst: usize) -> Vec<LinkId> {
        let mut out = Vec::new();
        self.route_into(src, dst, &mut out);
        out
    }

    /// Allocation-free form of [`Topology::route`]: clears `out` and
    /// appends the same links in the same order (the skeleton replay VM
    /// recycles route vectors through this).
    pub fn route_into(&self, src: usize, dst: usize, out: &mut Vec<LinkId>) {
        out.clear();
        if src == dst {
            // Intra-node: loopback only.
            out.push((3 * src + 2) as LinkId);
            return;
        }
        match self {
            Topology::Star { .. } => {
                out.push((3 * src) as LinkId);
                out.push((3 * dst + 1) as LinkId);
            }
            Topology::FatTree { down_leaf, leaves: _, tops, para, .. } => {
                let src_leaf = src / down_leaf;
                let dst_leaf = dst / down_leaf;
                if src_leaf == dst_leaf {
                    // Stays under one leaf switch (non-blocking).
                    out.push((3 * src) as LinkId);
                    out.push((3 * dst + 1) as LinkId);
                    return;
                }
                // Deterministic per-pair lane choice (ECMP-style hash).
                // A strong mix avoids harmonic collisions between HPL's
                // highly structured communication patterns and
                // power-of-two lane counts.
                let lanes = tops * para;
                let mut h = (src as u64) << 32 | dst as u64;
                h = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
                h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
                h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
                h ^= h >> 31;
                let lane = (h % lanes as u64) as usize;
                let top = lane / para;
                let k = lane % para;
                let trunk_base = 3 * self.nodes();
                let up_idx = trunk_base + 2 * ((src_leaf * tops + top) * para + k);
                let down_idx = trunk_base + 2 * ((dst_leaf * tops + top) * para + k) + 1;
                out.push((3 * src) as LinkId);
                out.push(up_idx as LinkId);
                out.push(down_idx as LinkId);
                out.push((3 * dst + 1) as LinkId);
            }
        }
    }

    /// Number of distinct trunk lanes (for tests / diagnostics).
    pub fn trunk_lanes(&self) -> usize {
        match self {
            Topology::Star { .. } => 0,
            Topology::FatTree { tops, para, .. } => tops * para,
        }
    }

    /// Serialize for campaign manifests (see `coordinator::manifest`).
    pub fn to_json(&self) -> Json {
        match self {
            Topology::Star { nodes, caps } => Json::obj(vec![
                ("kind", Json::Str("star".into())),
                ("nodes", Json::Num(*nodes as f64)),
                ("caps", Json::arr_f64(caps)),
            ]),
            Topology::FatTree { nodes, down_leaf, leaves, tops, para, caps } => {
                Json::obj(vec![
                    ("kind", Json::Str("fat-tree".into())),
                    ("nodes", Json::Num(*nodes as f64)),
                    ("down_leaf", Json::Num(*down_leaf as f64)),
                    ("leaves", Json::Num(*leaves as f64)),
                    ("tops", Json::Num(*tops as f64)),
                    ("para", Json::Num(*para as f64)),
                    ("caps", Json::arr_f64(caps)),
                ])
            }
        }
    }

    /// Inverse of [`Topology::to_json`], checking the link-count
    /// invariants the router relies on.
    pub fn from_json(v: &Json) -> Option<Topology> {
        let caps = v.get("caps")?.f64_vec()?;
        match v.get("kind")?.as_str()? {
            "star" => {
                let nodes = v.get("nodes")?.as_usize()?;
                (caps.len() == 3 * nodes).then_some(Topology::Star { nodes, caps })
            }
            "fat-tree" => {
                let nodes = v.get("nodes")?.as_usize()?;
                let down_leaf = v.get("down_leaf")?.as_usize()?;
                let leaves = v.get("leaves")?.as_usize()?;
                let tops = v.get("tops")?.as_usize()?;
                let para = v.get("para")?.as_usize()?;
                (nodes == down_leaf * leaves
                    && tops >= 1
                    && para >= 1
                    && caps.len() == 3 * nodes + 2 * leaves * tops * para)
                    .then_some(Topology::FatTree {
                        nodes,
                        down_leaf,
                        leaves,
                        tops,
                        para,
                        caps,
                    })
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn star_routes() {
        let t = Topology::star(4, 1e9, 4e9);
        assert_eq!(t.route(1, 3), vec![3, 10]);
        assert_eq!(t.route(2, 2), vec![8]);
        assert_eq!(t.link_capacities().len(), 12);
    }

    #[test]
    fn fat_tree_shape() {
        // Paper's (2; 32, 8; 1, N; 1, 8) with N = 2: 256 nodes.
        let t = Topology::fat_tree(32, 8, 2, 8, 1e9, 1e9, 4e9);
        assert_eq!(t.nodes(), 256);
        assert_eq!(t.trunk_lanes(), 16);
        // 3 links per node + 2 per (leaf, top, parallel).
        assert_eq!(t.link_capacities().len(), 3 * 256 + 2 * 8 * 2 * 8);
    }

    #[test]
    fn fat_tree_same_leaf_avoids_trunk() {
        let t = Topology::fat_tree(32, 8, 2, 8, 1e9, 1e9, 4e9);
        let r = t.route(0, 31); // same leaf
        assert_eq!(r.len(), 2);
        let r = t.route(0, 32); // different leaves
        assert_eq!(r.len(), 4);
        let trunk_base = 3 * 256;
        assert!(r[1] as usize >= trunk_base && r[2] as usize >= trunk_base);
    }

    #[test]
    fn fat_tree_routes_valid_and_spread() {
        let t = Topology::fat_tree(32, 8, 4, 8, 1e9, 1e9, 4e9);
        let ncaps = t.link_capacities().len();
        let mut used = std::collections::HashSet::new();
        for src in (0..256).step_by(7) {
            for dst in (0..256).step_by(11) {
                let r = t.route(src, dst);
                for &l in &r {
                    assert!((l as usize) < ncaps, "link out of range");
                }
                if src / 32 != dst / 32 {
                    used.insert(r[1]);
                }
            }
        }
        // D-mod-k routing should spread across many distinct up-links.
        assert!(used.len() > 8, "only {} trunk lanes used", used.len());
    }

    #[test]
    fn json_roundtrip_both_kinds() {
        let star = Topology::star(4, 12.5e9, 40e9);
        let tree = Topology::fat_tree(32, 8, 2, 8, 1e9, 0.5e9, 4e9);
        for t in [star, tree] {
            let back =
                Topology::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
            // Topology has no PartialEq; the Debug form covers every field.
            assert_eq!(format!("{t:?}"), format!("{back:?}"));
            // Routing must be unaffected by the round-trip.
            assert_eq!(t.route(0, 1), back.route(0, 1));
            assert_eq!(t.route(2, 2), back.route(2, 2));
        }
    }

    #[test]
    fn json_rejects_inconsistent_link_counts() {
        let mut v = Topology::star(4, 1e9, 4e9).to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("nodes".into(), Json::Num(5.0)); // caps no longer match
        }
        assert!(Topology::from_json(&v).is_none());
        assert!(Topology::from_json(&Json::parse("{\"kind\":\"ring\"}").unwrap()).is_none());
    }

    #[test]
    fn fewer_tops_fewer_lanes() {
        let t1 = Topology::fat_tree(32, 8, 1, 8, 1e9, 1e9, 4e9);
        let t4 = Topology::fat_tree(32, 8, 4, 8, 1e9, 1e9, 4e9);
        assert_eq!(t1.trunk_lanes(), 8);
        assert_eq!(t4.trunk_lanes(), 32);
    }
}
