//! Max-min fair bandwidth sharing by progressive filling.
//!
//! Given link capacities and the set of flows (each a list of links it
//! crosses), compute the unique max-min fair allocation: repeatedly find
//! the most contended link, fix its flows at the equal share, remove
//! their consumption everywhere, repeat.
//!
//! The solver is *incremental-friendly*: a [`LinkLoad`] maintains the
//! per-link flow counts and the sorted set of loaded links across
//! reshares, so a flow add/remove touches only the links on that flow's
//! route, and each fixing round scans only the loaded links instead of
//! every link in the topology (a fat-tree has thousands of links but a
//! handful carry flows at any instant). The allocation is exactly — bit
//! for bit — what the from-scratch reference computes; debug builds
//! assert that on every solve.

use super::topology::LinkId;

/// Per-link flow counts plus the ascending set of links with at least
/// one flow, maintained incrementally across reshares: adding or
/// removing a flow touches only the links on its route. Feeding this to
/// [`max_min_rates_staged`] turns the per-round bottleneck scan from
/// O(all links) into O(loaded links).
#[derive(Default, Clone)]
pub struct LinkLoad {
    counts: Vec<u32>,
    /// Links with `counts > 0`, kept sorted ascending (the solver's
    /// first-strict-minimum tie-break is defined on ascending link id).
    active: Vec<LinkId>,
}

impl LinkLoad {
    /// Grow the count table to cover `nl` links (never shrinks).
    pub fn ensure_links(&mut self, nl: usize) {
        if self.counts.len() < nl {
            self.counts.resize(nl, 0);
        }
    }

    /// Drop every flow (O(loaded links), not O(all links)).
    pub fn clear(&mut self) {
        for &l in &self.active {
            self.counts[l as usize] = 0;
        }
        self.active.clear();
    }

    pub fn add_route(&mut self, route: &[LinkId]) {
        for &l in route {
            let c = &mut self.counts[l as usize];
            if *c == 0 {
                let pos = self.active.binary_search(&l).unwrap_err();
                self.active.insert(pos, l);
            }
            *c += 1;
        }
    }

    pub fn remove_route(&mut self, route: &[LinkId]) {
        for &l in route {
            let c = &mut self.counts[l as usize];
            debug_assert!(*c > 0, "removing a route that was never added");
            *c -= 1;
            if *c == 0 {
                let pos = self.active.binary_search(&l).expect("loaded link is active");
                self.active.remove(pos);
            }
        }
    }

    pub fn count(&self, l: usize) -> u32 {
        self.counts[l]
    }

    /// The loaded links, ascending.
    pub fn active(&self) -> &[LinkId] {
        &self.active
    }
}

/// Reusable buffers for the solver: the residual-capacity vector is
/// O(links) (about a thousand entries on the paper's fat-trees), and
/// resharing runs on every flow arrival/departure — a workspace held by
/// the network turns those per-reshare allocations into `clear()`s.
/// Routes are staged *flat* ([`Workspace::begin_routes`] /
/// [`Workspace::push_route`]) so a reshare never allocates a
/// `Vec<&[LinkId]>` either.
#[derive(Default)]
pub struct Workspace {
    residual: Vec<f64>,
    unfixed: Vec<u32>,
    fixed: Vec<bool>,
    out: Vec<f64>,
    route_flat: Vec<LinkId>,
    route_off: Vec<usize>,
    scan: Vec<LinkId>,
    load: LinkLoad,
}

impl Workspace {
    /// Start staging a fresh set of flow routes.
    pub fn begin_routes(&mut self) {
        self.route_flat.clear();
        self.route_off.clear();
        self.route_off.push(0);
    }

    /// Stage the next flow's route. Flow order is allocation order: the
    /// progressive-filling subtraction order depends on it, so callers
    /// must push routes in the same order on every path that claims
    /// bit-identical rates.
    pub fn push_route(&mut self, route: &[LinkId]) {
        self.route_flat.extend_from_slice(route);
        self.route_off.push(self.route_flat.len());
    }
}

/// Compute max-min fair rates. `routes[i]` lists the links of flow `i`.
/// Returns one rate per flow (bytes/s).
pub fn max_min_rates(caps: &[f64], routes: &[&[LinkId]]) -> Vec<f64> {
    let mut ws = Workspace::default();
    max_min_rates_into(caps, routes, &mut ws);
    ws.out
}

/// Allocation-reusing form of [`max_min_rates`]: identical results,
/// with every scratch vector drawn from `ws`. The result lives in the
/// returned slice (valid until the next call).
pub fn max_min_rates_into<'w>(
    caps: &[f64],
    routes: &[&[LinkId]],
    ws: &'w mut Workspace,
) -> &'w [f64] {
    ws.begin_routes();
    let Workspace { route_flat, route_off, load, .. } = &mut *ws;
    load.ensure_links(caps.len());
    load.clear();
    for r in routes {
        route_flat.extend_from_slice(r);
        route_off.push(route_flat.len());
        load.add_route(r);
    }
    let Workspace { residual, unfixed, fixed, out, route_flat, route_off, scan, load } = ws;
    solve(caps, load, route_flat, route_off, residual, unfixed, fixed, scan, out);
    out
}

/// Solve over routes already staged in `ws` (via
/// [`Workspace::begin_routes`]/[`Workspace::push_route`]) and a
/// [`LinkLoad`] maintained incrementally by the caller. The load's
/// counts must equal the per-link route counts of the staged routes —
/// this is the reshare fast path where a flow add/remove has already
/// updated only its own links.
pub fn max_min_rates_staged<'w>(
    caps: &[f64],
    load: &LinkLoad,
    ws: &'w mut Workspace,
) -> &'w [f64] {
    let Workspace { residual, unfixed, fixed, out, route_flat, route_off, scan, .. } = ws;
    solve(caps, load, route_flat, route_off, residual, unfixed, fixed, scan, out);
    out
}

/// The progressive-filling core. Only links in `load.active()` are
/// seeded and scanned; every other `residual`/`unfixed` entry is stale
/// from a previous solve and provably never read, because flows only
/// cross links the load counts. Bottleneck candidates live in `scan`, a
/// per-solve copy of the active list compacted in place as links drain —
/// ascending order is preserved, so the first-strict-minimum tie-break
/// matches the from-scratch full scan exactly.
#[allow(clippy::too_many_arguments)]
fn solve(
    caps: &[f64],
    load: &LinkLoad,
    route_flat: &[LinkId],
    route_off: &[usize],
    residual: &mut Vec<f64>,
    unfixed: &mut Vec<u32>,
    fixed: &mut Vec<bool>,
    scan: &mut Vec<LinkId>,
    out: &mut Vec<f64>,
) {
    let nf = route_off.len().saturating_sub(1);
    let nl = caps.len();
    out.clear();
    out.resize(nf, 0.0);
    if nf == 0 {
        return;
    }
    // Lazy seeding: grow without zeroing, then write only active links.
    if residual.len() < nl {
        residual.resize(nl, 0.0);
    }
    if unfixed.len() < nl {
        unfixed.resize(nl, 0);
    }
    fixed.clear();
    fixed.resize(nf, false);
    scan.clear();
    scan.extend_from_slice(load.active());
    for &l in scan.iter() {
        residual[l as usize] = caps[l as usize];
        unfixed[l as usize] = load.count(l as usize);
    }
    let mut remaining = nf;
    while remaining > 0 {
        // Bottleneck link: minimal fair share among links with unfixed
        // flows, first strict minimum in ascending link order. Drained
        // links are compacted out of the candidate list as we pass.
        let mut best_link = usize::MAX;
        let mut best_share = f64::INFINITY;
        let mut w = 0;
        for r in 0..scan.len() {
            let l = scan[r] as usize;
            if unfixed[l] == 0 {
                continue;
            }
            scan[w] = scan[r];
            w += 1;
            let share = residual[l].max(0.0) / unfixed[l] as f64;
            if share < best_share {
                best_share = share;
                best_link = l;
            }
        }
        scan.truncate(w);
        if best_link == usize::MAX {
            // Remaining flows cross no links at all: unconstrained. Give
            // them an effectively infinite rate (placeholder; routes are
            // never empty in practice).
            for (i, r) in out.iter_mut().enumerate() {
                if !fixed[i] {
                    *r = f64::INFINITY;
                }
            }
            break;
        }
        // Fix every unfixed flow crossing the bottleneck, in flow order.
        for i in 0..nf {
            let route = &route_flat[route_off[i]..route_off[i + 1]];
            if fixed[i] || !route.iter().any(|&l| l as usize == best_link) {
                continue;
            }
            fixed[i] = true;
            remaining -= 1;
            out[i] = best_share;
            for &l in route {
                residual[l as usize] -= best_share;
                unfixed[l as usize] -= 1;
            }
        }
    }
    #[cfg(debug_assertions)]
    {
        let routes: Vec<&[LinkId]> = (0..nf)
            .map(|i| &route_flat[route_off[i]..route_off[i + 1]])
            .collect();
        let want = max_min_rates_reference(caps, &routes);
        for (i, (&got, &want)) in out.iter().zip(&want).enumerate() {
            assert!(
                got.to_bits() == want.to_bits(),
                "incremental solver diverged from reference at flow {i}: \
                 {got:?} != {want:?}"
            );
        }
    }
}

/// The from-scratch O(links)-per-round implementation this module
/// shipped with, kept verbatim as the bit-exactness oracle: debug
/// builds check every [`solve`] against it, and the property tests
/// below randomize over it. Do not "optimize" this function — its
/// f64 operation order *is* the contract.
pub fn max_min_rates_reference(caps: &[f64], routes: &[&[LinkId]]) -> Vec<f64> {
    let nf = routes.len();
    let nl = caps.len();
    let mut rate = vec![0.0; nf];
    if nf == 0 {
        return rate;
    }
    let mut residual = caps.to_vec();
    let mut unfixed_per_link = vec![0usize; nl];
    let mut fixed = vec![false; nf];
    for r in routes {
        for &l in *r {
            unfixed_per_link[l as usize] += 1;
        }
    }
    let mut remaining = nf;
    while remaining > 0 {
        // Bottleneck link: minimal fair share among links with unfixed flows.
        let mut best_link = usize::MAX;
        let mut best_share = f64::INFINITY;
        for l in 0..nl {
            if unfixed_per_link[l] > 0 {
                let share = residual[l].max(0.0) / unfixed_per_link[l] as f64;
                if share < best_share {
                    best_share = share;
                    best_link = l;
                }
            }
        }
        if best_link == usize::MAX {
            for (i, r) in rate.iter_mut().enumerate() {
                if !fixed[i] {
                    *r = f64::INFINITY;
                }
            }
            break;
        }
        // Fix every unfixed flow crossing the bottleneck.
        for i in 0..nf {
            if fixed[i] || !routes[i].iter().any(|&l| l as usize == best_link) {
                continue;
            }
            fixed[i] = true;
            remaining -= 1;
            rate[i] = best_share;
            for &l in routes[i] {
                residual[l as usize] -= best_share;
                unfixed_per_link[l as usize] -= 1;
            }
        }
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_min_capacity_on_route() {
        let caps = [10.0, 4.0, 8.0];
        let routes: Vec<&[LinkId]> = vec![&[0, 1, 2]];
        assert_eq!(max_min_rates(&caps, &routes), vec![4.0]);
    }

    #[test]
    fn equal_share_on_shared_link() {
        let caps = [9.0];
        let routes: Vec<&[LinkId]> = vec![&[0], &[0], &[0]];
        assert_eq!(max_min_rates(&caps, &routes), vec![3.0, 3.0, 3.0]);
    }

    #[test]
    fn classic_max_min_example() {
        // Flow 0 crosses both links; flow 1 only link0; flow 2 only link1.
        // link0 cap 10, link1 cap 4: flow0 and flow2 bottleneck on link1
        // at 2 each; flow1 then gets the rest of link0 = 8.
        let caps = [10.0, 4.0];
        let routes: Vec<&[LinkId]> = vec![&[0, 1], &[0], &[1]];
        let r = max_min_rates(&caps, &routes);
        assert_eq!(r, vec![2.0, 8.0, 2.0]);
    }

    #[test]
    fn allocation_is_feasible_and_saturates_a_bottleneck() {
        // Randomized feasibility property.
        let mut rng = crate::stats::Rng::new(9);
        for _ in 0..50 {
            let nl = 2 + rng.below(6);
            let caps: Vec<f64> = (0..nl).map(|_| rng.uniform_in(1.0, 10.0)).collect();
            let nf = 1 + rng.below(8);
            let routes_owned: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = 1 + rng.below(3.min(nl));
                    let mut ls: Vec<LinkId> = Vec::new();
                    while ls.len() < len {
                        let l = rng.below(nl) as LinkId;
                        if !ls.contains(&l) {
                            ls.push(l);
                        }
                    }
                    ls
                })
                .collect();
            let routes: Vec<&[LinkId]> = routes_owned.iter().map(|r| r.as_slice()).collect();
            let rates = max_min_rates(&caps, &routes);
            // Feasibility: no link oversubscribed.
            let mut load = vec![0.0; nl];
            for (r, rt) in rates.iter().zip(&routes_owned) {
                assert!(*r > 0.0);
                for &l in rt {
                    load[l as usize] += r;
                }
            }
            for l in 0..nl {
                assert!(load[l] <= caps[l] + 1e-9, "link {l} over: {} > {}", load[l], caps[l]);
            }
            // Pareto: every flow crosses at least one saturated link.
            for rt in &routes_owned {
                let sat = rt
                    .iter()
                    .any(|&l| (caps[l as usize] - load[l as usize]).abs() < 1e-6);
                assert!(sat, "flow not bottlenecked anywhere");
            }
        }
    }

    #[test]
    fn empty_inputs() {
        assert!(max_min_rates(&[1.0], &[]).is_empty());
    }

    /// Property: the incremental active-set solver is bit-identical to
    /// the from-scratch reference over randomized capacities and routes
    /// — including skewed capacities that force share ties, sparse link
    /// usage (most links idle, the incremental solver's home turf), and
    /// a reused workspace carrying stale residuals between solves.
    #[test]
    fn incremental_solver_is_bit_identical_to_reference() {
        let mut rng = crate::stats::Rng::new(0xC0FFEE);
        let mut ws = Workspace::default();
        for round in 0..400 {
            let nl = 1 + rng.below(40);
            let caps: Vec<f64> = (0..nl)
                .map(|_| {
                    // A fifth of the links share one exact capacity so
                    // equal-share ties exercise the tie-break order.
                    if rng.below(5) == 0 {
                        4.0
                    } else {
                        rng.uniform_in(0.5, 20.0)
                    }
                })
                .collect();
            let nf = rng.below(12);
            let routes_owned: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = 1 + rng.below(4.min(nl));
                    let mut ls: Vec<LinkId> = Vec::new();
                    while ls.len() < len {
                        let l = rng.below(nl) as LinkId;
                        if !ls.contains(&l) {
                            ls.push(l);
                        }
                    }
                    ls
                })
                .collect();
            let routes: Vec<&[LinkId]> =
                routes_owned.iter().map(|r| r.as_slice()).collect();
            let want = max_min_rates_reference(&caps, &routes);
            let got = max_min_rates_into(&caps, &routes, &mut ws);
            assert_eq!(got.len(), want.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "round {round} flow {i}: {g:?} != {w:?}"
                );
            }
        }
    }

    /// Property: a [`LinkLoad`] maintained by interleaved add/remove
    /// equals one rebuilt from scratch over the surviving routes, and
    /// [`max_min_rates_staged`] over it matches the reference.
    #[test]
    fn incremental_link_load_tracks_from_scratch_rebuild() {
        let mut rng = crate::stats::Rng::new(31337);
        let nl = 25usize;
        let caps: Vec<f64> = (0..nl).map(|_| rng.uniform_in(1.0, 10.0)).collect();
        let mut load = LinkLoad::default();
        load.ensure_links(nl);
        let mut ws = Workspace::default();
        let mut live: Vec<Vec<LinkId>> = Vec::new();
        for step in 0..300 {
            if !live.is_empty() && rng.below(2) == 0 {
                let victim = rng.below(live.len());
                let route = live.remove(victim);
                load.remove_route(&route);
            } else {
                let len = 1 + rng.below(4);
                let mut ls: Vec<LinkId> = Vec::new();
                while ls.len() < len {
                    let l = rng.below(nl) as LinkId;
                    if !ls.contains(&l) {
                        ls.push(l);
                    }
                }
                load.add_route(&ls);
                live.push(ls);
            }
            // The maintained load must equal a from-scratch rebuild.
            let mut fresh = LinkLoad::default();
            fresh.ensure_links(nl);
            for r in &live {
                fresh.add_route(r);
            }
            assert_eq!(load.active(), fresh.active(), "step {step}");
            for l in 0..nl {
                assert_eq!(load.count(l), fresh.count(l), "step {step} link {l}");
            }
            // And the staged solve over it must match the reference.
            ws.begin_routes();
            for r in &live {
                ws.push_route(r);
            }
            let routes: Vec<&[LinkId]> = live.iter().map(|r| r.as_slice()).collect();
            let want = max_min_rates_reference(&caps, &routes);
            let got = max_min_rates_staged(&caps, &load, &mut ws);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "step {step} flow {i}: {g:?} != {w:?}"
                );
            }
        }
    }
}
