//! Max-min fair bandwidth sharing by progressive filling.
//!
//! Given link capacities and the set of flows (each a list of links it
//! crosses), compute the unique max-min fair allocation: repeatedly find
//! the most contended link, fix its flows at the equal share, remove
//! their consumption everywhere, repeat.

use super::topology::LinkId;

/// Reusable buffers for [`max_min_rates_into`]: the residual-capacity
/// vector is O(links) (about a thousand entries on the paper's
/// fat-trees), and resharing runs on every flow arrival/departure — a
/// workspace held by the network turns those per-reshare allocations
/// into `clear()`s.
#[derive(Default)]
pub struct Workspace {
    residual: Vec<f64>,
    unfixed: Vec<usize>,
    fixed: Vec<bool>,
    out: Vec<f64>,
}

/// Compute max-min fair rates. `routes[i]` lists the links of flow `i`.
/// Returns one rate per flow (bytes/s).
pub fn max_min_rates(caps: &[f64], routes: &[&[LinkId]]) -> Vec<f64> {
    let mut ws = Workspace::default();
    max_min_rates_into(caps, routes, &mut ws);
    ws.out
}

/// Allocation-reusing form of [`max_min_rates`]: identical algorithm
/// and arithmetic, with every scratch vector drawn from `ws`. The
/// result lives in the returned slice (valid until the next call).
pub fn max_min_rates_into<'w>(
    caps: &[f64],
    routes: &[&[LinkId]],
    ws: &'w mut Workspace,
) -> &'w [f64] {
    let nf = routes.len();
    let nl = caps.len();
    let rate = &mut ws.out;
    rate.clear();
    rate.resize(nf, 0.0);
    if nf == 0 {
        return rate;
    }
    let residual = &mut ws.residual;
    residual.clear();
    residual.extend_from_slice(caps);
    let unfixed_per_link = &mut ws.unfixed;
    unfixed_per_link.clear();
    unfixed_per_link.resize(nl, 0);
    let fixed = &mut ws.fixed;
    fixed.clear();
    fixed.resize(nf, false);
    for r in routes {
        for &l in *r {
            unfixed_per_link[l as usize] += 1;
        }
    }
    let mut remaining = nf;
    while remaining > 0 {
        // Bottleneck link: minimal fair share among links with unfixed flows.
        let mut best_link = usize::MAX;
        let mut best_share = f64::INFINITY;
        for l in 0..nl {
            if unfixed_per_link[l] > 0 {
                let share = residual[l].max(0.0) / unfixed_per_link[l] as f64;
                if share < best_share {
                    best_share = share;
                    best_link = l;
                }
            }
        }
        if best_link == usize::MAX {
            // Remaining flows cross no links at all: unconstrained. Give
            // them an effectively infinite rate (placeholder; routes are
            // never empty in practice).
            for (i, r) in rate.iter_mut().enumerate() {
                if !fixed[i] {
                    *r = f64::INFINITY;
                }
            }
            break;
        }
        // Fix every unfixed flow crossing the bottleneck.
        for i in 0..nf {
            if fixed[i] || !routes[i].iter().any(|&l| l as usize == best_link) {
                continue;
            }
            fixed[i] = true;
            remaining -= 1;
            rate[i] = best_share;
            for &l in routes[i] {
                residual[l as usize] -= best_share;
                unfixed_per_link[l as usize] -= 1;
            }
        }
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_min_capacity_on_route() {
        let caps = [10.0, 4.0, 8.0];
        let routes: Vec<&[LinkId]> = vec![&[0, 1, 2]];
        assert_eq!(max_min_rates(&caps, &routes), vec![4.0]);
    }

    #[test]
    fn equal_share_on_shared_link() {
        let caps = [9.0];
        let routes: Vec<&[LinkId]> = vec![&[0], &[0], &[0]];
        assert_eq!(max_min_rates(&caps, &routes), vec![3.0, 3.0, 3.0]);
    }

    #[test]
    fn classic_max_min_example() {
        // Flow 0 crosses both links; flow 1 only link0; flow 2 only link1.
        // link0 cap 10, link1 cap 4: flow0 and flow2 bottleneck on link1
        // at 2 each; flow1 then gets the rest of link0 = 8.
        let caps = [10.0, 4.0];
        let routes: Vec<&[LinkId]> = vec![&[0, 1], &[0], &[1]];
        let r = max_min_rates(&caps, &routes);
        assert_eq!(r, vec![2.0, 8.0, 2.0]);
    }

    #[test]
    fn allocation_is_feasible_and_saturates_a_bottleneck() {
        // Randomized feasibility property.
        let mut rng = crate::stats::Rng::new(9);
        for _ in 0..50 {
            let nl = 2 + rng.below(6);
            let caps: Vec<f64> = (0..nl).map(|_| rng.uniform_in(1.0, 10.0)).collect();
            let nf = 1 + rng.below(8);
            let routes_owned: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = 1 + rng.below(3.min(nl));
                    let mut ls: Vec<LinkId> = Vec::new();
                    while ls.len() < len {
                        let l = rng.below(nl) as LinkId;
                        if !ls.contains(&l) {
                            ls.push(l);
                        }
                    }
                    ls
                })
                .collect();
            let routes: Vec<&[LinkId]> = routes_owned.iter().map(|r| r.as_slice()).collect();
            let rates = max_min_rates(&caps, &routes);
            // Feasibility: no link oversubscribed.
            let mut load = vec![0.0; nl];
            for (r, rt) in rates.iter().zip(&routes_owned) {
                assert!(*r > 0.0);
                for &l in rt {
                    load[l as usize] += r;
                }
            }
            for l in 0..nl {
                assert!(load[l] <= caps[l] + 1e-9, "link {l} over: {} > {}", load[l], caps[l]);
            }
            // Pareto: every flow crosses at least one saturated link.
            for rt in &routes_owned {
                let sat = rt
                    .iter()
                    .any(|&l| (caps[l as usize] - load[l as usize]).abs() < 1e-6);
                assert!(sat, "flow not bottlenecked anywhere");
            }
        }
    }

    #[test]
    fn empty_inputs() {
        assert!(max_min_rates(&[1.0], &[]).is_empty());
    }
}
