//! Piecewise-linear protocol model (SMPI-style "smpi/bw-factor" and
//! "smpi/lat-factor" generalization).
//!
//! A [`NetModel`] maps a communication class (intra-node vs inter-node)
//! and a message size to a [`Segment`]: an additive latency and a
//! multiplicative bandwidth factor. Protocol thresholds (async, eager,
//! rendezvous) live here too, because they are part of what a network
//! calibration estimates.

use std::collections::BTreeMap;

/// Communication class.
#[derive(Clone, Copy, Debug, Eq, Hash, Ord, PartialEq, PartialOrd)]
pub enum NetClass {
    /// Same node (shared memory).
    Local,
    /// Different nodes (through the interconnect).
    Remote,
}

/// One piece of the piecewise model: applies to messages of size
/// `<= max_bytes` (pieces are sorted; the first matching piece wins).
#[derive(Clone, Copy, Debug)]
pub struct Segment {
    pub max_bytes: f64,
    /// Additive per-message latency in seconds.
    pub latency: f64,
    /// Multiplicative factor on link bandwidth (1.0 = nominal; the
    /// > 160 MB Infiniband DMA-locking drop of §4.1 is a factor < 1).
    pub bw_factor: f64,
}

/// Piecewise-linear protocol model per class + protocol thresholds.
#[derive(Clone, Debug)]
pub struct NetModel {
    pub classes: BTreeMap<NetClass, Vec<Segment>>,
    /// Below this size the send is buffered: the sender does not block.
    pub async_threshold: f64,
    /// Above this size the transfer uses the rendezvous protocol: the
    /// sender blocks until the receiver posts the matching receive.
    pub rendezvous_threshold: f64,
}

impl NetModel {
    /// No latency, nominal bandwidth — used by unit tests.
    pub fn ideal() -> NetModel {
        let seg = vec![Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 }];
        let mut classes = BTreeMap::new();
        classes.insert(NetClass::Local, seg.clone());
        classes.insert(NetClass::Remote, seg);
        NetModel {
            classes,
            async_threshold: 0.0,
            rendezvous_threshold: f64::INFINITY,
        }
    }

    /// Look up the applicable segment for a message.
    pub fn segment(&self, class: NetClass, bytes: f64) -> Segment {
        let segs = self
            .classes
            .get(&class)
            .unwrap_or_else(|| &self.classes[&NetClass::Remote]);
        for s in segs {
            if bytes <= s.max_bytes {
                return *s;
            }
        }
        *segs.last().expect("model has at least one segment")
    }

    /// Build a model from (size, latency, bw_factor) breakpoints.
    pub fn from_segments(
        local: Vec<Segment>,
        remote: Vec<Segment>,
        async_threshold: f64,
        rendezvous_threshold: f64,
    ) -> NetModel {
        assert!(!local.is_empty() && !remote.is_empty());
        let mut classes = BTreeMap::new();
        classes.insert(NetClass::Local, local);
        classes.insert(NetClass::Remote, remote);
        NetModel { classes, async_threshold, rendezvous_threshold }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_lookup_picks_first_match() {
        let m = NetModel::from_segments(
            vec![Segment { max_bytes: f64::INFINITY, latency: 1e-7, bw_factor: 1.0 }],
            vec![
                Segment { max_bytes: 1e3, latency: 1e-6, bw_factor: 0.5 },
                Segment { max_bytes: 1e6, latency: 2e-6, bw_factor: 0.9 },
                Segment { max_bytes: f64::INFINITY, latency: 4e-6, bw_factor: 1.0 },
            ],
            64.0,
            65536.0,
        );
        assert_eq!(m.segment(NetClass::Remote, 500.0).bw_factor, 0.5);
        assert_eq!(m.segment(NetClass::Remote, 5e5).bw_factor, 0.9);
        assert_eq!(m.segment(NetClass::Remote, 5e8).bw_factor, 1.0);
        assert_eq!(m.segment(NetClass::Local, 5e8).latency, 1e-7);
    }

    #[test]
    fn boundary_is_inclusive() {
        let m = NetModel::from_segments(
            vec![Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 }],
            vec![
                Segment { max_bytes: 1e3, latency: 1e-6, bw_factor: 0.5 },
                Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 },
            ],
            0.0,
            f64::INFINITY,
        );
        assert_eq!(m.segment(NetClass::Remote, 1e3).bw_factor, 0.5);
        assert_eq!(m.segment(NetClass::Remote, 1e3 + 1.0).bw_factor, 1.0);
    }
}
