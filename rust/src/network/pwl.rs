//! Piecewise-linear protocol model (SMPI-style "smpi/bw-factor" and
//! "smpi/lat-factor" generalization).
//!
//! A [`NetModel`] maps a communication class (intra-node vs inter-node)
//! and a message size to a [`Segment`]: an additive latency and a
//! multiplicative bandwidth factor. Protocol thresholds (async, eager,
//! rendezvous) live here too, because they are part of what a network
//! calibration estimates.

use std::collections::BTreeMap;

use crate::stats::json::Json;

/// Communication class.
#[derive(Clone, Copy, Debug, Eq, Hash, Ord, PartialEq, PartialOrd)]
pub enum NetClass {
    /// Same node (shared memory).
    Local,
    /// Different nodes (through the interconnect).
    Remote,
}

impl NetClass {
    pub fn name(&self) -> &'static str {
        match self {
            NetClass::Local => "local",
            NetClass::Remote => "remote",
        }
    }

    pub fn parse(s: &str) -> Option<NetClass> {
        match s {
            "local" => Some(NetClass::Local),
            "remote" => Some(NetClass::Remote),
            _ => None,
        }
    }
}

/// One piece of the piecewise model: applies to messages of size
/// `<= max_bytes` (pieces are sorted; the first matching piece wins).
#[derive(Clone, Copy, Debug)]
pub struct Segment {
    pub max_bytes: f64,
    /// Additive per-message latency in seconds.
    pub latency: f64,
    /// Multiplicative factor on link bandwidth (1.0 = nominal; the
    /// > 160 MB Infiniband DMA-locking drop of §4.1 is a factor < 1).
    pub bw_factor: f64,
}

impl Segment {
    // Possibly-infinite values (`max_bytes` of the last piece, the
    // rendezvous threshold) use `Json::num_exact`, whose string encoding
    // survives the minimal JSON grammar's lack of an `inf` literal.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_bytes", Json::num_exact(self.max_bytes)),
            ("latency", Json::num_exact(self.latency)),
            ("bw_factor", Json::num_exact(self.bw_factor)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Segment> {
        Some(Segment {
            max_bytes: v.get("max_bytes")?.as_f64_exact()?,
            latency: v.get("latency")?.as_f64_exact()?,
            bw_factor: v.get("bw_factor")?.as_f64_exact()?,
        })
    }
}

/// Piecewise-linear protocol model per class + protocol thresholds.
#[derive(Clone, Debug)]
pub struct NetModel {
    pub classes: BTreeMap<NetClass, Vec<Segment>>,
    /// Below this size the send is buffered: the sender does not block.
    pub async_threshold: f64,
    /// Above this size the transfer uses the rendezvous protocol: the
    /// sender blocks until the receiver posts the matching receive.
    pub rendezvous_threshold: f64,
}

impl NetModel {
    /// No latency, nominal bandwidth — used by unit tests.
    pub fn ideal() -> NetModel {
        let seg = vec![Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 }];
        let mut classes = BTreeMap::new();
        classes.insert(NetClass::Local, seg.clone());
        classes.insert(NetClass::Remote, seg);
        NetModel {
            classes,
            async_threshold: 0.0,
            rendezvous_threshold: f64::INFINITY,
        }
    }

    /// Look up the applicable segment for a message.
    ///
    /// Every constructor ([`NetModel::from_segments`],
    /// [`NetModel::from_json`]) guarantees both classes are present and
    /// non-empty (see [`NetModel::validate`]), so the fallbacks here are
    /// defensive only — the lookup never panics, even on a hand-built
    /// model that skipped validation.
    pub fn segment(&self, class: NetClass, bytes: f64) -> Segment {
        // First *non-empty* class along the fallback chain, so a
        // present-but-empty entry still falls through to a usable one.
        let segs = [class, NetClass::Remote, NetClass::Local]
            .iter()
            .find_map(|c| self.classes.get(c).filter(|s| !s.is_empty()));
        let Some(segs) = segs else {
            return Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 };
        };
        for s in segs {
            if bytes <= s.max_bytes {
                return *s;
            }
        }
        *segs.last().expect("filtered non-empty above")
    }

    /// The invariant [`NetModel::segment`] relies on: both communication
    /// classes present, each with at least one piece.
    pub fn validate(&self) -> Result<(), String> {
        for class in [NetClass::Local, NetClass::Remote] {
            match self.classes.get(&class) {
                None => return Err(format!("net model: missing '{}' class", class.name())),
                Some(segs) if segs.is_empty() => {
                    return Err(format!("net model: '{}' class has no segments", class.name()))
                }
                Some(_) => {}
            }
        }
        Ok(())
    }

    /// Build a model from (size, latency, bw_factor) breakpoints.
    pub fn from_segments(
        local: Vec<Segment>,
        remote: Vec<Segment>,
        async_threshold: f64,
        rendezvous_threshold: f64,
    ) -> NetModel {
        let mut classes = BTreeMap::new();
        classes.insert(NetClass::Local, local);
        classes.insert(NetClass::Remote, remote);
        let m = NetModel { classes, async_threshold, rendezvous_threshold };
        if let Err(e) = m.validate() {
            panic!("NetModel::from_segments: {e}");
        }
        m
    }

    /// Serialize for campaign manifests (see `coordinator::manifest`).
    pub fn to_json(&self) -> Json {
        let classes: Vec<(&str, Json)> = self
            .classes
            .iter()
            .map(|(c, segs)| {
                (c.name(), Json::Arr(segs.iter().map(Segment::to_json).collect()))
            })
            .collect();
        Json::obj(vec![
            ("async_threshold", Json::num_exact(self.async_threshold)),
            ("rendezvous_threshold", Json::num_exact(self.rendezvous_threshold)),
            ("classes", Json::obj(classes)),
        ])
    }

    /// Inverse of [`NetModel::to_json`]. Enforces [`NetModel::validate`]
    /// so a deserialized model can never hit the `segment` fallbacks.
    pub fn from_json(v: &Json) -> Option<NetModel> {
        let mut classes = BTreeMap::new();
        for (name, segs_v) in v.get("classes")?.as_obj()? {
            let segs: Option<Vec<Segment>> =
                segs_v.as_arr()?.iter().map(Segment::from_json).collect();
            classes.insert(NetClass::parse(name)?, segs?);
        }
        let m = NetModel {
            classes,
            async_threshold: v.get("async_threshold")?.as_f64_exact()?,
            rendezvous_threshold: v.get("rendezvous_threshold")?.as_f64_exact()?,
        };
        m.validate().ok()?;
        Some(m)
    }
}

/// Fallback-resolved segment tables: one flat, non-empty segment list
/// per class, precomputed once so the per-message hot path
/// ([`SegTable::lookup`]) is a linear scan with no `BTreeMap` walk and
/// no allocation. Built from a [`NetModel`] by applying the exact
/// fallback chain of [`NetModel::segment`] up front; the two lookups
/// agree bit-for-bit on every (class, size), which
/// `seg_table_matches_segment_everywhere` pins down.
#[derive(Clone, Debug, Default)]
pub struct SegTable {
    local: Vec<Segment>,
    remote: Vec<Segment>,
}

impl SegTable {
    pub fn new(model: &NetModel) -> SegTable {
        let mut t = SegTable::default();
        t.rebuild(model);
        t
    }

    /// In-place [`SegTable::new`]: refills the tables without giving up
    /// their capacity (the replay arena rebuilds per point).
    pub fn rebuild(&mut self, model: &NetModel) {
        fn resolve_into(model: &NetModel, class: NetClass, out: &mut Vec<Segment>) {
            out.clear();
            match [class, NetClass::Remote, NetClass::Local]
                .iter()
                .find_map(|c| model.classes.get(c).filter(|s| !s.is_empty()))
            {
                Some(s) => out.extend_from_slice(s),
                None => out.push(Segment {
                    max_bytes: f64::INFINITY,
                    latency: 0.0,
                    bw_factor: 1.0,
                }),
            }
        }
        resolve_into(model, NetClass::Local, &mut self.local);
        resolve_into(model, NetClass::Remote, &mut self.remote);
    }

    /// Allocation-free equivalent of [`NetModel::segment`].
    pub fn lookup(&self, class: NetClass, bytes: f64) -> Segment {
        let segs = match class {
            NetClass::Local => &self.local,
            NetClass::Remote => &self.remote,
        };
        for s in segs {
            if bytes <= s.max_bytes {
                return *s;
            }
        }
        *segs.last().expect("SegTable classes are never empty")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seg_table_matches_segment_everywhere() {
        let full = NetModel::from_segments(
            vec![Segment { max_bytes: 4096.0, latency: 1e-7, bw_factor: 1.0 },
                 Segment { max_bytes: f64::INFINITY, latency: 3e-7, bw_factor: 0.7 }],
            vec![
                Segment { max_bytes: 1e3, latency: 1e-6, bw_factor: 0.5 },
                Segment { max_bytes: 1e6, latency: 2e-6, bw_factor: 0.9 },
                Segment { max_bytes: f64::INFINITY, latency: 4e-6, bw_factor: 1.0 },
            ],
            64.0,
            65536.0,
        );
        // A degenerate hand-built model exercises the fallback chain.
        let mut degenerate = full.clone();
        degenerate.classes.insert(NetClass::Local, Vec::new());
        let empty =
            NetModel { classes: BTreeMap::new(), async_threshold: 0.0, rendezvous_threshold: 0.0 };
        for m in [&full, &degenerate, &empty] {
            let t = SegTable::new(m);
            for class in [NetClass::Local, NetClass::Remote] {
                for bytes in [0.0, 1.0, 1e3, 1e3 + 1.0, 4096.0, 5e5, 1e6, 1e9] {
                    let a = m.segment(class, bytes);
                    let b = t.lookup(class, bytes);
                    assert_eq!(a.max_bytes, b.max_bytes);
                    assert_eq!(a.latency, b.latency);
                    assert_eq!(a.bw_factor, b.bw_factor);
                }
            }
        }
    }

    #[test]
    fn segment_lookup_picks_first_match() {
        let m = NetModel::from_segments(
            vec![Segment { max_bytes: f64::INFINITY, latency: 1e-7, bw_factor: 1.0 }],
            vec![
                Segment { max_bytes: 1e3, latency: 1e-6, bw_factor: 0.5 },
                Segment { max_bytes: 1e6, latency: 2e-6, bw_factor: 0.9 },
                Segment { max_bytes: f64::INFINITY, latency: 4e-6, bw_factor: 1.0 },
            ],
            64.0,
            65536.0,
        );
        assert_eq!(m.segment(NetClass::Remote, 500.0).bw_factor, 0.5);
        assert_eq!(m.segment(NetClass::Remote, 5e5).bw_factor, 0.9);
        assert_eq!(m.segment(NetClass::Remote, 5e8).bw_factor, 1.0);
        assert_eq!(m.segment(NetClass::Local, 5e8).latency, 1e-7);
    }

    #[test]
    fn json_roundtrip_with_infinities() {
        let m = NetModel::from_segments(
            vec![Segment { max_bytes: f64::INFINITY, latency: 1e-7, bw_factor: 1.0 }],
            vec![
                Segment { max_bytes: 65536.0, latency: 1.2e-6, bw_factor: 0.9 },
                Segment { max_bytes: f64::INFINITY, latency: 2.5e-6, bw_factor: 1.0 },
            ],
            8192.0,
            f64::INFINITY,
        );
        let back = NetModel::from_json(&Json::parse(&m.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.async_threshold, 8192.0);
        assert_eq!(back.rendezvous_threshold, f64::INFINITY);
        for class in [NetClass::Local, NetClass::Remote] {
            let (a, b) = (&m.classes[&class], &back.classes[&class]);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.max_bytes, y.max_bytes);
                assert_eq!(x.latency, y.latency);
                assert_eq!(x.bw_factor, y.bw_factor);
            }
        }
    }

    #[test]
    fn json_rejects_incomplete_models() {
        // A model without the remote class must fail at deserialization,
        // not panic later inside segment().
        let text = r#"{"async_threshold":0,"rendezvous_threshold":"inf",
                       "classes":{"local":[{"max_bytes":"inf","latency":0,"bw_factor":1}]}}"#;
        assert!(NetModel::from_json(&Json::parse(text).unwrap()).is_none());
        // Present but empty is rejected too.
        let text = r#"{"async_threshold":0,"rendezvous_threshold":"inf",
                       "classes":{"local":[{"max_bytes":"inf","latency":0,"bw_factor":1}],
                                  "remote":[]}}"#;
        assert!(NetModel::from_json(&Json::parse(text).unwrap()).is_none());
    }

    #[test]
    fn segment_never_panics_on_hand_built_models() {
        // A hand-built model that skipped validation (only Local
        // present): the lookup degrades gracefully instead of indexing
        // the absent Remote class.
        let mut classes = BTreeMap::new();
        classes.insert(
            NetClass::Local,
            vec![Segment { max_bytes: f64::INFINITY, latency: 3e-7, bw_factor: 0.8 }],
        );
        let m = NetModel { classes, async_threshold: 0.0, rendezvous_threshold: 1e9 };
        assert!(m.validate().is_err());
        assert_eq!(m.segment(NetClass::Remote, 1e6).latency, 3e-7);
        // A present-but-empty class falls through to a non-empty one
        // instead of masking it.
        let mut both = m.clone();
        both.classes.insert(NetClass::Remote, Vec::new());
        assert_eq!(both.segment(NetClass::Remote, 1e6).latency, 3e-7);
        // Fully empty: the nominal segment.
        let empty =
            NetModel { classes: BTreeMap::new(), async_threshold: 0.0, rendezvous_threshold: 0.0 };
        assert_eq!(empty.segment(NetClass::Remote, 1e6).bw_factor, 1.0);
    }

    #[test]
    fn boundary_is_inclusive() {
        let m = NetModel::from_segments(
            vec![Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 }],
            vec![
                Segment { max_bytes: 1e3, latency: 1e-6, bw_factor: 0.5 },
                Segment { max_bytes: f64::INFINITY, latency: 0.0, bw_factor: 1.0 },
            ],
            0.0,
            f64::INFINITY,
        );
        assert_eq!(m.segment(NetClass::Remote, 1e3).bw_factor, 0.5);
        assert_eq!(m.segment(NetClass::Remote, 1e3 + 1.0).bw_factor, 1.0);
    }
}
