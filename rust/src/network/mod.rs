//! Flow-level network model (SimGrid-style).
//!
//! Each ongoing point-to-point transfer is a *flow* crossing a route of
//! links; contention is resolved by max-min fair bandwidth sharing
//! (progressive filling), re-solved whenever a flow starts or finishes —
//! the steady-state fluid model SimGrid validates in [Velho et al. 2013].
//!
//! On top of the fluid layer sits a piecewise-linear *protocol model*
//! ([`pwl::NetModel`]): per message-size segment and per communication
//! class (intra-node vs inter-node), an added latency and a bandwidth
//! factor. This is how both the ground-truth platform (which includes
//! the > 160 MB bandwidth drop of §4.1) and the calibrated models
//! (optimistic vs improved) are expressed.

pub mod pwl;
pub mod sharing;
pub mod topology;

pub use pwl::{NetClass, NetModel, SegTable, Segment};
pub use topology::{LinkId, Topology};

use std::cell::RefCell;
use std::rc::Rc;

use crate::engine::{Signal, Sim};

/// A flow in progress.
struct Flow {
    route: Vec<LinkId>,
    /// Remaining *effective* bytes (already divided by the bandwidth factor).
    remaining: f64,
    /// Current max-min rate in bytes/s.
    rate: f64,
    done: Signal,
}

struct NetState {
    /// Link capacities in bytes/s (index = LinkId).
    caps: Vec<f64>,
    flows: Vec<Option<Flow>>,
    free: Vec<usize>,
    /// Last simulated time at which `remaining` was advanced.
    last: f64,
    /// Bumped on every reshare; stale completion watchers exit.
    epoch: u64,
    active: usize,
    /// Per-link flow counts + loaded-link set, maintained incrementally
    /// on flow add/remove so each reshare solves over the loaded links
    /// only (bit-identical to the from-scratch solve — see `sharing`).
    load: sharing::LinkLoad,
}

/// The network: topology + fluid flows + protocol model.
#[derive(Clone)]
pub struct Network {
    sim: Sim,
    topo: Rc<Topology>,
    model: Rc<NetModel>,
    /// Flattened segment tables: `segment()` without the per-call
    /// fallback-chain HashMap probes (hot: twice per message).
    segs: Rc<SegTable>,
    state: Rc<RefCell<NetState>>,
    /// Scratch buffers for max-min resharing (separate cell from
    /// `state` so a reshare can borrow both without conflict).
    ws: Rc<RefCell<sharing::Workspace>>,
}

impl Network {
    pub fn new(sim: Sim, topo: Topology, model: NetModel) -> Network {
        let caps = topo.link_capacities().to_vec();
        let segs = Rc::new(SegTable::new(&model));
        let mut load = sharing::LinkLoad::default();
        load.ensure_links(caps.len());
        Network {
            sim,
            topo: Rc::new(topo),
            model: Rc::new(model),
            segs,
            state: Rc::new(RefCell::new(NetState {
                caps,
                flows: Vec::new(),
                free: Vec::new(),
                last: 0.0,
                epoch: 0,
                active: 0,
                load,
            })),
            ws: Rc::new(RefCell::new(sharing::Workspace::default())),
        }
    }

    pub fn model(&self) -> &NetModel {
        &self.model
    }

    /// Protocol segment for a transfer of `bytes` in `class` — the
    /// flattened fast path (no fallback-chain probes), used by the MPI
    /// send path which looks a segment up once per message.
    pub fn seg(&self, class: NetClass, bytes: f64) -> Segment {
        self.segs.lookup(class, bytes)
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Number of flows currently in the fluid system.
    pub fn active_flows(&self) -> usize {
        self.state.borrow().active
    }

    /// Classify a (src, dst) node pair.
    pub fn class_of(&self, src_node: usize, dst_node: usize) -> NetClass {
        if src_node == dst_node {
            NetClass::Local
        } else {
            NetClass::Remote
        }
    }

    /// Time a transfer of `bytes` would take on an *empty* network
    /// (used by calibration procedures to build piecewise models).
    pub fn unloaded_time(&self, src_node: usize, dst_node: usize, bytes: f64) -> f64 {
        let class = self.class_of(src_node, dst_node);
        let seg = self.segs.lookup(class, bytes);
        let route = self.topo.route(src_node, dst_node);
        let bw = route
            .iter()
            .map(|&l| self.topo.link_capacities()[l as usize])
            .fold(f64::INFINITY, f64::min);
        seg.latency + bytes / (bw * seg.bw_factor)
    }

    /// Perform a transfer; completes (in simulated time) when the last
    /// byte arrives. The payload crosses the fluid layer, so concurrent
    /// transfers contend on shared links.
    pub async fn transfer(&self, src_node: usize, dst_node: usize, bytes: f64) {
        debug_assert!(bytes >= 0.0);
        let class = self.class_of(src_node, dst_node);
        let seg = self.segs.lookup(class, bytes);
        if seg.latency > 0.0 {
            self.sim.sleep(seg.latency).await;
        }
        if bytes <= 0.0 {
            return;
        }
        let effective = bytes / seg.bw_factor.max(1e-12);
        let done = self.start_flow(src_node, dst_node, effective);
        done.wait().await;
    }

    /// Insert a flow and return its completion signal.
    fn start_flow(&self, src_node: usize, dst_node: usize, effective_bytes: f64) -> Signal {
        let route = self.topo.route(src_node, dst_node);
        let done = Signal::new();
        {
            let mut st = self.state.borrow_mut();
            let now = self.sim.now();
            Self::advance(&mut st, now);
            st.load.add_route(&route);
            let flow = Flow {
                route,
                remaining: effective_bytes.max(1.0),
                rate: 0.0,
                done: done.clone(),
            };
            let id = match st.free.pop() {
                Some(i) => {
                    st.flows[i] = Some(flow);
                    i
                }
                None => {
                    st.flows.push(Some(flow));
                    st.flows.len() - 1
                }
            };
            let _ = id;
            st.active += 1;
            Self::reshare(&mut st, &mut self.ws.borrow_mut());
        }
        self.schedule_watcher();
        done
    }

    /// Advance all flows' remaining bytes to time `now`.
    fn advance(st: &mut NetState, now: f64) {
        let dt = now - st.last;
        if dt > 0.0 {
            for f in st.flows.iter_mut().flatten() {
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
        }
        st.last = now;
    }

    /// Recompute max-min rates; bumps the epoch. Routes are staged flat
    /// into the workspace (no per-reshare Vec of indices or slices) and
    /// the solve runs over the incrementally maintained link load —
    /// both in ascending slab order, matching the from-scratch path's
    /// f64 operation order exactly.
    fn reshare(st: &mut NetState, ws: &mut sharing::Workspace) {
        st.epoch += 1;
        ws.begin_routes();
        for f in st.flows.iter().flatten() {
            ws.push_route(&f.route);
        }
        let rates = sharing::max_min_rates_staged(&st.caps, &st.load, ws);
        for (f, &r) in st.flows.iter_mut().flatten().zip(rates) {
            f.rate = r;
        }
    }

    /// Earliest completion among active flows.
    fn next_completion(st: &NetState) -> Option<f64> {
        let mut best: Option<f64> = None;
        for f in st.flows.iter().flatten() {
            if f.rate > 0.0 {
                let t = st.last + f.remaining / f.rate;
                best = Some(match best {
                    Some(b) => b.min(t),
                    None => t,
                });
            }
        }
        best
    }

    /// Spawn a watcher for the current earliest completion.
    fn schedule_watcher(&self) {
        let (epoch, at) = {
            let st = self.state.borrow();
            match Self::next_completion(&st) {
                Some(t) => (st.epoch, t),
                None => return,
            }
        };
        let net = self.clone();
        let sim = self.sim.clone();
        self.sim.spawn(async move {
            sim.sleep_until(at).await;
            net.on_tick(epoch);
        });
    }

    /// Completion tick: if the epoch is still current, retire finished
    /// flows and reshare.
    fn on_tick(&self, epoch: u64) {
        let mut finished: Vec<Signal> = Vec::new();
        {
            let mut st = self.state.borrow_mut();
            if st.epoch != epoch {
                return; // stale watcher
            }
            let now = self.sim.now();
            Self::advance(&mut st, now);
            // Retire flows that are done (tolerance: < 1e-3 effective
            // bytes, i.e. sub-picosecond at any realistic rate).
            for i in 0..st.flows.len() {
                let done = match &st.flows[i] {
                    Some(f) => f.remaining <= 1e-3,
                    None => false,
                };
                if done {
                    let f = st.flows[i].take().unwrap();
                    st.load.remove_route(&f.route);
                    st.free.push(i);
                    st.active -= 1;
                    finished.push(f.done);
                }
            }
            if !finished.is_empty() {
                Self::reshare(&mut st, &mut self.ws.borrow_mut());
            }
        }
        for s in finished {
            s.set();
        }
        self.schedule_watcher();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star(nodes: usize, bw: f64) -> Network {
        let sim = Sim::new();
        let topo = Topology::star(nodes, bw, 4.0 * bw);
        Network::new(sim, topo, NetModel::ideal())
    }

    #[test]
    fn single_flow_full_bandwidth() {
        let sim = Sim::new();
        let topo = Topology::star(4, 1e9, 4e9);
        let net = Network::new(sim.clone(), topo, NetModel::ideal());
        let n = net.clone();
        let h = sim.spawn_join(async move {
            n.transfer(0, 1, 1e9).await;
        });
        let s = sim.clone();
        sim.spawn(async move {
            h.await;
            // 1e9 bytes over 1e9 B/s = 1s.
            assert!((s.now() - 1.0).abs() < 1e-9, "t={}", s.now());
        });
        sim.run();
    }

    #[test]
    fn two_flows_share_receiver_link() {
        let sim = Sim::new();
        let topo = Topology::star(4, 1e9, 4e9);
        let net = Network::new(sim.clone(), topo, NetModel::ideal());
        // Both flows target node 2: its down-link is the bottleneck.
        for src in [0, 1] {
            let n = net.clone();
            let s = sim.clone();
            sim.spawn(async move {
                n.transfer(src, 2, 1e9).await;
                assert!((s.now() - 2.0).abs() < 1e-6, "t={}", s.now());
            });
        }
        sim.run();
    }

    #[test]
    fn disjoint_flows_do_not_contend() {
        let sim = Sim::new();
        let topo = Topology::star(4, 1e9, 4e9);
        let net = Network::new(sim.clone(), topo, NetModel::ideal());
        for (src, dst) in [(0, 1), (2, 3)] {
            let n = net.clone();
            let s = sim.clone();
            sim.spawn(async move {
                n.transfer(src, dst, 1e9).await;
                assert!((s.now() - 1.0).abs() < 1e-6, "t={}", s.now());
            });
        }
        sim.run();
    }

    #[test]
    fn late_flow_slows_down_early_flow() {
        let sim = Sim::new();
        let topo = Topology::star(4, 1e9, 4e9);
        let net = Network::new(sim.clone(), topo, NetModel::ideal());
        {
            let n = net.clone();
            let s = sim.clone();
            sim.spawn(async move {
                n.transfer(0, 2, 1e9).await;
                // 0.5 s alone (0.5e9 done), 0.5 s at half rate (0.25e9),
                // then the contender leaves: 0.25e9 at full rate.
                assert!((s.now() - 1.25).abs() < 1e-6, "t={}", s.now());
            });
        }
        {
            let n = net.clone();
            let s = sim.clone();
            sim.spawn(async move {
                s.sleep(0.5).await;
                n.transfer(1, 2, 0.25e9).await;
                // Shares at 0.5e9 B/s: 0.25e9 bytes -> 0.5s -> ends at 1.0s.
                assert!((s.now() - 1.0).abs() < 1e-6, "t={}", s.now());
            });
        }
        sim.run();
    }

    #[test]
    fn intra_node_uses_loopback() {
        let net = star(2, 1e9);
        assert_eq!(net.class_of(0, 0), NetClass::Local);
        assert_eq!(net.class_of(0, 1), NetClass::Remote);
        // Loopback at 4x bandwidth.
        let t_local = net.unloaded_time(0, 0, 1e9);
        let t_remote = net.unloaded_time(0, 1, 1e9);
        assert!(t_local < t_remote);
    }

    #[test]
    fn zero_byte_transfer_costs_latency_only() {
        let sim = Sim::new();
        let topo = Topology::star(2, 1e9, 4e9);
        let mut model = NetModel::ideal();
        model.classes.insert(
            NetClass::Remote,
            vec![Segment { max_bytes: f64::INFINITY, latency: 1e-5, bw_factor: 1.0 }],
        );
        let net = Network::new(sim.clone(), topo, model);
        let s = sim.clone();
        sim.spawn(async move {
            net.transfer(0, 1, 0.0).await;
            assert!((s.now() - 1e-5).abs() < 1e-12);
        });
        sim.run();
    }
}
