//! End-to-end validation driver (recorded in EXPERIMENTS.md): the full
//! Fig. 5 pipeline on a real (small) workload, proving all three layers
//! compose:
//!
//!   ground truth  →  synthetic calibration benchmarks
//!                 →  model fit (through the AOT-compiled XLA artifact
//!                    when available — Pallas gram kernel + Cholesky
//!                    solve via PJRT — else the bit-equivalent pure-Rust
//!                    OLS path)
//!                 →  HPL emulation (pooled artifact durations, or
//!                    direct sampling)
//!                 →  prediction-vs-reality error ladder.
//!
//! Asserts the paper's §3.4 finding: naive ≫ heterogeneous ≳ full, with
//! the full model within a few percent.
//!
//! Run with:  cargo run --release --example validate_hpl [-- --bench --out DIR]
//! (CI runs the `--bench` sizes as the end-to-end smoke tier.)

use std::rc::Rc;

use hplsim::calibration::calibrate_models;
use hplsim::coordinator::{ExpCtx, Scale, Table};
use hplsim::hpl::HplConfig;
use hplsim::platform::{calibrate_network, CalProcedure, GroundTruth, Scenario};
use hplsim::runtime::Artifacts;
use hplsim::stats::{mean, std_dev};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (_, opts) = hplsim::coordinator::cli::parse_args(&args);
    let bench = opts.contains_key("bench");
    let out_dir: std::path::PathBuf =
        opts.get("out").map(|s| s.into()).unwrap_or_else(|| "results".into());

    let arts = match Artifacts::load_default() {
        Ok(a) => {
            println!("PJRT platform: {}", a.platform());
            Some(Rc::new(a))
        }
        Err(e) => {
            println!("artifacts unavailable ({e}); using the pure-Rust model path");
            None
        }
    };
    // ExpCtx::sim dispatches to the artifact pipeline or the pure-Rust
    // path — the same policy the experiment registry uses.
    let ctx = ExpCtx::new(arts, Scale::Bench, 42);

    let gt = GroundTruth::generate(8, Scenario::Normal, 42);
    let topo = gt.topology();
    let net_truth = gt.net_model();
    let net_cal = calibrate_network(&gt, CalProcedure::Improved, 43);
    let models = calibrate_models(ctx.arts.as_deref(), &gt, 0, 512, 44);

    let n_list: &[usize] = if bench { &[2048, 4096] } else { &[4096, 8192, 16384] };
    let mut worst = [0.0f64; 3]; // naive, hetero, full |err|
    let mut table = Table::new(
        "validate_hpl — predictions vs reality (GFlop/s)",
        &[
            "N", "reality", "sd", "naive", "err-naive", "hetero", "err-hetero",
            "full", "err-full",
        ],
    );
    println!(
        "\n{:>6} {:>9} {:>6} {:>9} {:>8} {:>9} {:>8} {:>9} {:>8}",
        "N", "reality", "sd", "naive", "err", "hetero", "err", "full", "err"
    );
    for &n in n_list {
        let mut cfg = HplConfig::dahu_default(n, 4, 8);
        cfg.nb = 64;
        let reality: Vec<f64> = (0..3u64)
            .map(|d| ctx.sim(&cfg, &topo, &net_truth, &gt.day_model(d), 4, 100 + d).gflops)
            .collect();
        let rm = mean(&reality);
        let mut preds = [0.0f64; 3];
        for (i, m) in [&models.naive, &models.hetero, &models.full].iter().enumerate() {
            preds[i] = ctx.sim(&cfg, &topo, &net_cal, m, 4, 7).gflops;
            worst[i] = worst[i].max((preds[i] / rm - 1.0).abs());
        }
        println!(
            "{:>6} {:>9.1} {:>6.1} {:>9.1} {:>+7.1}% {:>9.1} {:>+7.1}% {:>9.1} {:>+7.1}%",
            n,
            rm,
            std_dev(&reality),
            preds[0],
            100.0 * (preds[0] / rm - 1.0),
            preds[1],
            100.0 * (preds[1] / rm - 1.0),
            preds[2],
            100.0 * (preds[2] / rm - 1.0),
        );
        table.row(vec![
            n.to_string(),
            format!("{rm:.1}"),
            format!("{:.1}", std_dev(&reality)),
            format!("{:.1}", preds[0]),
            format!("{:+.1}%", 100.0 * (preds[0] / rm - 1.0)),
            format!("{:.1}", preds[1]),
            format!("{:+.1}%", 100.0 * (preds[1] / rm - 1.0)),
            format!("{:.1}", preds[2]),
            format!("{:+.1}%", 100.0 * (preds[2] / rm - 1.0)),
        ]);
    }
    if let Err(e) = table.write_csv(&out_dir, "validate_hpl") {
        eprintln!("warning: could not write validate_hpl.csv: {e}");
    }

    println!(
        "\nworst |error|: naive {:+.1}%  hetero {:+.1}%  full {:+.1}%",
        100.0 * worst[0],
        100.0 * worst[1],
        100.0 * worst[2]
    );
    // The paper's ladder: the naive model is far off, the full model is
    // within a few percent. (The hetero-vs-full ordering and the tight
    // 5% bound hold at the larger default sizes; at bench scale the two
    // best models sit within noise of each other.)
    assert!(worst[0] > worst[2], "naive must be worse than the full model");
    if bench {
        assert!(worst[2] < 0.10, "full model must predict within 10% at bench scale");
    } else {
        assert!(worst[1] > worst[2], "heterogeneous must be worse than full");
        assert!(worst[2] < 0.05, "full model must predict within 5%");
    }
    println!("validation PASSED: model-fidelity ladder reproduced");
}
