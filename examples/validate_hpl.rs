//! End-to-end validation driver (recorded in EXPERIMENTS.md): the full
//! Fig. 5 pipeline on a real (small) workload, proving all three layers
//! compose:
//!
//!   ground truth  →  synthetic calibration benchmarks
//!                 →  model fit through the AOT-compiled XLA artifact
//!                    (Pallas gram kernel + Cholesky solve, via PJRT)
//!                 →  HPL emulation with pooled durations evaluated by
//!                    the dgemm_model artifact (Pallas poly kernel)
//!                 →  prediction-vs-reality error ladder.
//!
//! Asserts the paper's §3.4 finding: naive ≫ heterogeneous > full, with
//! the full model within a few percent.
//!
//! Run with:  make artifacts && cargo run --release --example validate_hpl

use hplsim::calibration::calibrate_models;
use hplsim::hpl::{simulate_with_artifacts, HplConfig};
use hplsim::platform::{calibrate_network, CalProcedure, GroundTruth, Scenario};
use hplsim::runtime::Artifacts;
use hplsim::stats::{mean, std_dev};

fn main() {
    let arts = match Artifacts::load_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("validate_hpl requires the XLA artifacts (run `make artifacts`): {e:#}");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", arts.platform());

    let gt = GroundTruth::generate(8, Scenario::Normal, 42);
    let topo = gt.topology();
    let net_truth = gt.net_model();
    let net_cal = calibrate_network(&gt, CalProcedure::Improved, 43);
    let models = calibrate_models(Some(&arts), &gt, 0, 512, 44);

    let mut worst = [0.0f64; 3]; // naive, hetero, full |err|
    println!(
        "\n{:>6} {:>9} {:>6} {:>9} {:>8} {:>9} {:>8} {:>9} {:>8}",
        "N", "reality", "sd", "naive", "err", "hetero", "err", "full", "err"
    );
    for n in [4096usize, 8192, 16384] {
        let mut cfg = HplConfig::dahu_default(n, 4, 8);
        cfg.nb = 64;
        let reality: Vec<f64> = (0..3u64)
            .map(|d| {
                simulate_with_artifacts(
                    &cfg, &topo, &net_truth, &gt.day_model(d), &arts, 4, 100 + d,
                )
                .unwrap()
                .gflops
            })
            .collect();
        let rm = mean(&reality);
        let mut preds = [0.0f64; 3];
        for (i, m) in [&models.naive, &models.hetero, &models.full].iter().enumerate() {
            preds[i] = simulate_with_artifacts(&cfg, &topo, &net_cal, m, &arts, 4, 7)
                .unwrap()
                .gflops;
            worst[i] = worst[i].max((preds[i] / rm - 1.0).abs());
        }
        println!(
            "{:>6} {:>9.1} {:>6.1} {:>9.1} {:>+7.1}% {:>9.1} {:>+7.1}% {:>9.1} {:>+7.1}%",
            n,
            rm,
            std_dev(&reality),
            preds[0],
            100.0 * (preds[0] / rm - 1.0),
            preds[1],
            100.0 * (preds[1] / rm - 1.0),
            preds[2],
            100.0 * (preds[2] / rm - 1.0),
        );
    }

    println!(
        "\nworst |error|: naive {:+.1}%  hetero {:+.1}%  full {:+.1}%",
        100.0 * worst[0],
        100.0 * worst[1],
        100.0 * worst[2]
    );
    // The paper's ladder: naive ≫ hetero > full; full within a few %.
    assert!(worst[0] > worst[1], "naive must be worse than heterogeneous");
    assert!(worst[1] > worst[2], "heterogeneous must be worse than full");
    assert!(worst[2] < 0.05, "full model must predict within 5%");
    println!("validation PASSED: model-fidelity ladder reproduced, full model within 5%");
}
