//! What-if capacity planning (the paper's §5): given only daily
//! calibration measurements of a small testbed, extrapolate a larger
//! hypothetical cluster with the hierarchical generative model and ask
//! (a) how much does dgemm temporal variability cost? and (b) how many
//! fat-tree top switches can be turned off?
//!
//! Run with:  cargo run --release --example whatif_capacity

use hplsim::calibration::{bench_node, fit_day_linear};
use hplsim::hpl::{simulate_direct, HplConfig};
use hplsim::network::Topology;
use hplsim::platform::{generative, GroundTruth, Hierarchical, Scenario};
use hplsim::stats::Rng;

fn main() {
    // Observe a 16-node testbed for 8 "days" (benchmark regressions).
    let gt = GroundTruth::generate(16, Scenario::Normal, 5);
    let mut rng = Rng::new(6);
    let data: Vec<Vec<[f64; 3]>> = (0..16)
        .map(|p| {
            (0..8u64)
                .map(|d| fit_day_linear(&bench_node(&gt, &gt.day_model(d), p, 250, &mut rng)))
                .collect()
        })
        .collect();
    let h = Hierarchical::fit(&data);
    println!(
        "fitted hierarchy: alpha = {:.3e}  spatial sd = {:.1}%  daily sd = {:.1}%",
        h.mu[0],
        100.0 * h.sigma_s[(0, 0)].sqrt() / h.mu[0],
        100.0 * h.sigma_t[(0, 0)].sqrt() / h.mu[0],
    );

    // Extrapolate a 64-node cluster that does not exist.
    let cluster = h.sample_cluster(64, &mut rng);
    let scaled: Vec<[f64; 3]> = cluster.iter().map(|c| [c[0] / 2.0, c[1], c[2] / 2.0]).collect();
    let mut cfg = HplConfig::dahu_default(16384, 8, 8);
    cfg.nb = 64;
    let net = gt.net_model();

    // (a) Temporal-variability sensitivity (Fig. 12).
    let star = Topology::star(64, gt.node_bw, gt.loop_bw);
    let t0 = simulate_direct(
        &cfg, &star, &net,
        &generative::model_from_linear(&scaled, Some(0.0)), 1, 1,
    )
    .seconds;
    println!("\ntemporal variability (64-node what-if):");
    for cv in [0.02, 0.05, 0.10] {
        let m = generative::model_from_linear(&scaled, Some(cv));
        let t = simulate_direct(&cfg, &star, &net, &m, 1, 2).seconds;
        println!("  cv = {cv:<4}: overhead {:+.1}%", 100.0 * (t / t0 - 1.0));
    }

    // (b) Fat-tree tapering sensitivity (Fig. 16).
    println!("\nfat-tree tapering (8 leaves x 8 nodes):");
    let model = generative::model_from_linear(&scaled, None);
    let mut base = 0.0;
    for tops in (1..=4).rev() {
        let ft = Topology::fat_tree(8, 8, tops, 2, gt.node_bw, gt.node_bw, gt.loop_bw);
        let g = simulate_direct(&cfg, &ft, &net, &model, 1, 3).gflops;
        if tops == 4 {
            base = g;
        }
        println!("  {tops} top switch(es): {g:8.1} GFlop/s ({:+.1}%)", 100.0 * (g / base - 1.0));
    }
}
