//! Quickstart: simulate one HPL configuration on a synthetic cluster and
//! compare "reality" (hidden ground truth) against the calibrated
//! prediction — the paper's Fig. 2 workflow in ~40 lines.
//!
//! Run with:  cargo run --release --example quickstart

use hplsim::calibration::calibrate_models;
use hplsim::hpl::{simulate_direct, simulate_with_artifacts, HplConfig};
use hplsim::platform::{calibrate_network, CalProcedure, GroundTruth, Scenario};
use hplsim::runtime::Artifacts;
use hplsim::stats::mean;

fn main() {
    // 1. A hidden 8-node cluster (the "real" machine).
    let gt = GroundTruth::generate(8, Scenario::Normal, 42);
    let topo = gt.topology();
    let net_truth = gt.net_model();

    // 2. Calibrate: benchmark dgemm on every node + network ping-pongs.
    let arts = Artifacts::load_default().ok();
    if let Some(a) = &arts {
        println!("using XLA artifacts on {}", a.platform());
    } else {
        println!("artifacts not built — falling back to the pure-Rust model path");
    }
    let models = calibrate_models(arts.as_ref(), &gt, 0, 512, 1);
    let net_cal = calibrate_network(&gt, CalProcedure::Improved, 2);

    // 3. An HPL configuration: N=8192, NB=64, 4x8 grid (4 ranks/node).
    let mut cfg = HplConfig::dahu_default(8192, 4, 8);
    cfg.nb = 64;

    // 4. "Real" runs (ground truth) ...
    let reality: Vec<f64> = (0..3)
        .map(|day| {
            let r = simulate_direct(&cfg, &topo, &net_truth, &gt.day_model(day), 4, 100 + day);
            println!("reality day {day}: {:8.2} GFlop/s ({:.3} s)", r.gflops, r.seconds);
            r.gflops
        })
        .collect();

    // 5. ... versus the prediction from calibrated models only.
    let pred = match &arts {
        Some(a) => {
            simulate_with_artifacts(&cfg, &topo, &net_cal, &models.full, a, 4, 7).unwrap()
        }
        None => simulate_direct(&cfg, &topo, &net_cal, &models.full, 4, 7),
    };
    let rm = mean(&reality);
    println!(
        "prediction   : {:8.2} GFlop/s  (error {:+.1}% — the paper predicts within a few %)",
        pred.gflops,
        100.0 * (pred.gflops / rm - 1.0)
    );
    assert!((pred.gflops / rm - 1.0).abs() < 0.10, "prediction off by >10%");
}
