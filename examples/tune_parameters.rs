//! Parameter tuning entirely in simulation (the paper's §4.2 use case):
//! sweep NB x DEPTH x BCAST x SWAP on the calibrated surrogate, rank the
//! factors by ANOVA, and report the best configuration — without ever
//! "running" the real machine except for calibration.
//!
//! Run with:  cargo run --release --example tune_parameters

use hplsim::calibration::calibrate_models;
use hplsim::hpl::{simulate_direct, Bcast, HplConfig, Rfact, SwapAlg};
use hplsim::platform::{calibrate_network, CalProcedure, GroundTruth, Scenario};
use hplsim::stats::anova_one_way;

fn main() {
    let gt = GroundTruth::generate(4, Scenario::Normal, 11);
    let topo = gt.topology();
    let net = calibrate_network(&gt, CalProcedure::Improved, 12);
    let models = calibrate_models(None, &gt, 0, 512, 13);

    let mut rows = Vec::new();
    let mut y = Vec::new();
    for nb in [32usize, 64] {
        for depth in [0usize, 1] {
            for bcast in Bcast::ALL {
                for swap in SwapAlg::ALL {
                    let cfg = HplConfig {
                        n: 4096,
                        nb,
                        p: 4,
                        q: 4,
                        depth,
                        bcast,
                        swap,
                        swap_threshold: 64,
                        rfact: Rfact::Right,
                        nbmin: 8,
                    };
                    let r = simulate_direct(&cfg, &topo, &net, &models.full, 4, 3);
                    rows.push((nb, depth, bcast, swap));
                    y.push(r.gflops);
                }
            }
        }
    }

    // Factor ranking (the paper found NB and DEPTH dominate, then
    // BCAST and SWAP).
    for (name, groups) in [
        ("nb", rows.iter().map(|r| r.0.to_string()).collect::<Vec<_>>()),
        ("depth", rows.iter().map(|r| r.1.to_string()).collect()),
        ("bcast", rows.iter().map(|r| r.2.name().to_string()).collect()),
        ("swap", rows.iter().map(|r| r.3.name().to_string()).collect()),
    ] {
        let a = anova_one_way(name, &groups, &y);
        println!("{name:>6}: eta^2 = {:.3}  F = {:.1}", a.eta_sq, a.f_stat);
    }

    let best = y
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let (nb, depth, bcast, swap) = rows[best];
    println!(
        "\nbest configuration in simulation: NB={nb} DEPTH={depth} BCAST={} SWAP={} \
         ({:.1} GFlop/s over {} combinations)",
        bcast.name(),
        swap.name(),
        y[best],
        y.len()
    );
    assert_eq!(y.len(), 72);
}
