"""AOT path: lowering to HLO text round-trips through the XLA parser."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from compile import aot, model


def test_lower_dgemm_model_to_hlo_text():
    lowered, _ = aot.lower_dgemm_model(512)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[512,4]" in text  # mnk input shape present


def test_lower_calibrate_to_hlo_text():
    lowered, _ = aot.lower_calibrate()
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert f"f32[{aot.CAL_P},{aot.CAL_S},4]" in text


def test_hlo_text_has_no_custom_calls():
    """The artifacts must be runnable by the plain CPU PJRT client:
    no Mosaic/LAPACK custom-calls may survive lowering."""
    for lowered in (aot.lower_dgemm_model(512)[0], aot.lower_calibrate()[0]):
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text, "artifact needs a custom runtime"


def test_aot_writes_artifacts_and_manifest(tmp_path):
    # Patch the batch list down so the test stays fast.
    old = aot.BATCHES
    aot.BATCHES = (512,)
    try:
        argv = sys.argv
        sys.argv = ["aot", "--out-dir", str(tmp_path)]
        try:
            aot.main()
        finally:
            sys.argv = argv
    finally:
        aot.BATCHES = old
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["feats"] == 8
    assert (tmp_path / "dgemm_model_512.hlo.txt").exists()
    assert (tmp_path / "calibrate.hlo.txt").exists()
    entry = manifest["dgemm_model_512"]
    assert entry["inputs"][0]["shape"] == [512, 4]
    assert entry["outputs"][0]["shape"] == [512]


def test_lowered_dgemm_executes_like_eager():
    """The exact jitted graph that gets exported matches eager numerics."""
    rng = np.random.default_rng(0)
    b, nodes = 512, aot.NODES
    mnk = np.zeros((b, 4), np.float32)
    mnk[:, 0] = rng.integers(16, 2048, b)
    mnk[:, 1] = rng.integers(16, 2048, b)
    mnk[:, 2] = rng.integers(16, 256, b)
    idx = rng.integers(0, 32, b).astype(np.int32)
    mu = np.abs(rng.normal(0, 1e-11, (nodes, 8))).astype(np.float32)
    sg = (mu * 0.03).astype(np.float32)
    z = rng.standard_normal(b).astype(np.float32)
    out = jax.jit(model.dgemm_model_entry)(mnk, idx, mu, sg, z)[0]
    ref_out = model.dgemm_model_entry(mnk, idx, mu, sg, z)[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), rtol=1e-6)
