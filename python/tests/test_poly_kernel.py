"""L1 correctness: Pallas poly_model kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import poly_model_durations
from compile.kernels.poly_model import BLOCK_B
from compile.kernels import ref


def _inputs(rng, b, sigma_scale=0.03):
    mnk = np.zeros((b, 4), np.float32)
    mnk[:, 0] = rng.integers(1, 8192, b)
    mnk[:, 1] = rng.integers(1, 8192, b)
    mnk[:, 2] = rng.integers(1, 1024, b)
    mu = np.abs(rng.normal(0, 1e-11, (b, 8))).astype(np.float32)
    mu[:, 5:] = 0
    sg = (mu * sigma_scale).astype(np.float32)
    z = rng.standard_normal(b).astype(np.float32)
    return mnk, mu, sg, z


def _run_both(mnk, mu, sg, z, block_b):
    got = poly_model_durations(
        jnp.array(mnk), jnp.array(mu), jnp.array(sg), jnp.array(z),
        block_b=block_b,
    )
    want = ref.ref_durations(
        jnp.array(mnk), jnp.array(mu), jnp.array(sg), jnp.array(z)
    )
    return np.asarray(got), np.asarray(want)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 6),
    block_b=st.sampled_from([8, 32, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
    sigma_scale=st.floats(0.0, 0.5),
)
def test_kernel_matches_ref(blocks, block_b, seed, sigma_scale):
    rng = np.random.default_rng(seed)
    mnk, mu, sg, z = _inputs(rng, blocks * block_b, sigma_scale)
    got, want = _run_both(mnk, mu, sg, z, block_b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-12)


def test_default_block_size():
    rng = np.random.default_rng(7)
    mnk, mu, sg, z = _inputs(rng, 4 * BLOCK_B)
    got, want = _run_both(mnk, mu, sg, z, BLOCK_B)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-12)


def test_zero_sigma_is_deterministic_polynomial():
    """sigma = 0 -> pure polynomial, independent of z."""
    rng = np.random.default_rng(1)
    mnk, mu, _, z = _inputs(rng, 256)
    sg = np.zeros_like(mu)
    got1, _ = _run_both(mnk, mu, sg, z, 256)
    got2, _ = _run_both(mnk, mu, sg, -z, 256)
    np.testing.assert_array_equal(got1, got2)
    feats = np.asarray(ref.ref_features(jnp.array(mnk)))
    np.testing.assert_allclose(got1, (feats * mu).sum(-1), rtol=1e-6)


def test_negative_sigma_clamped():
    """A (non-physical) negative sigma row behaves like sigma = 0."""
    rng = np.random.default_rng(2)
    mnk, mu, sg, z = _inputs(rng, 128)
    got_neg, _ = _run_both(mnk, mu, -sg, z, 128)
    got_zero, _ = _run_both(mnk, mu, np.zeros_like(sg), z, 128)
    np.testing.assert_array_equal(got_neg, got_zero)


def test_durations_nonnegative_even_with_negative_mu():
    rng = np.random.default_rng(3)
    mnk, mu, sg, z = _inputs(rng, 128)
    got, _ = _run_both(mnk, -mu, sg, z, 128)
    assert (got >= 0).all()


def test_z_sign_irrelevant():
    """Half-normal: |z| is used, so the sign of z must not matter."""
    rng = np.random.default_rng(4)
    mnk, mu, sg, z = _inputs(rng, 128)
    got_pos, _ = _run_both(mnk, mu, sg, np.abs(z), 128)
    got_neg, _ = _run_both(mnk, mu, sg, -np.abs(z), 128)
    np.testing.assert_array_equal(got_pos, got_neg)


def test_batch_must_divide_block():
    rng = np.random.default_rng(5)
    mnk, mu, sg, z = _inputs(rng, 100)
    with pytest.raises(AssertionError):
        poly_model_durations(
            jnp.array(mnk), jnp.array(mu), jnp.array(sg), jnp.array(z),
            block_b=64,
        )
