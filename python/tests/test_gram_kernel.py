"""L1 correctness: Pallas gram kernel vs the pure-jnp einsum oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gram
from compile.kernels import ref
from compile.kernels.poly_model import FEATS


def _feats(rng, p, s):
    mnk = np.zeros((p * s, 4), np.float32)
    mnk[:, 0] = rng.integers(1, 4096, p * s)
    mnk[:, 1] = rng.integers(1, 4096, p * s)
    mnk[:, 2] = rng.integers(1, 512, p * s)
    f = np.asarray(ref.ref_features(jnp.array(mnk))).reshape(p, s, FEATS)
    # Scale down so f32 Gram sums stay well conditioned in the comparison.
    return (f / np.maximum(np.abs(f).max(axis=(0, 1)), 1.0)).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(1, 8),
    s_blocks=st.integers(1, 4),
    block_s=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(p, s_blocks, block_s, seed):
    rng = np.random.default_rng(seed)
    f = _feats(rng, p, s_blocks * block_s)
    y = rng.standard_normal((p, s_blocks * block_s)).astype(np.float32)
    g, v = gram(jnp.array(f), jnp.array(y), block_s=block_s)
    g_ref, v_ref = ref.ref_gram(jnp.array(f), jnp.array(y))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=2e-4, atol=1e-4)


def test_gram_symmetry_and_psd():
    rng = np.random.default_rng(11)
    f = _feats(rng, 4, 256)
    y = rng.standard_normal((4, 256)).astype(np.float32)
    g, _ = gram(jnp.array(f), jnp.array(y), block_s=64)
    g = np.asarray(g, np.float64)
    np.testing.assert_allclose(g, np.swapaxes(g, 1, 2), rtol=1e-6, atol=1e-8)
    for p in range(4):
        eig = np.linalg.eigvalsh(g[p])
        assert eig.min() > -1e-4 * max(1.0, eig.max())


def test_gram_multi_block_accumulation_matches_single_block():
    """Grid accumulation over sample blocks == one big block."""
    rng = np.random.default_rng(12)
    f = _feats(rng, 2, 256)
    y = rng.standard_normal((2, 256)).astype(np.float32)
    g1, v1 = gram(jnp.array(f), jnp.array(y), block_s=256)
    g2, v2 = gram(jnp.array(f), jnp.array(y), block_s=32)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5, atol=1e-6)
