"""L2 correctness: dgemm_model gather path, solve_spd, calibration fit."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.poly_model import FEATS


def test_c_abs_constant_monte_carlo():
    """C_ABS = E||z| - sqrt(2/pi)| — cross-check the closed form."""
    z = np.random.default_rng(0).standard_normal(4_000_000)
    mc = np.abs(np.abs(z) - np.sqrt(2 / np.pi)).mean()
    assert abs(mc - model.C_ABS) < 5e-4


def test_dgemm_model_gathers_per_node_coefficients():
    rng = np.random.default_rng(5)
    nodes, b = 16, 512
    mnk = np.zeros((b, 4), np.float32)
    mnk[:, 0] = rng.integers(16, 2048, b)
    mnk[:, 1] = rng.integers(16, 2048, b)
    mnk[:, 2] = rng.integers(16, 256, b)
    idx = rng.integers(0, nodes, b).astype(np.int32)
    mu_tab = np.abs(rng.normal(0, 1e-11, (nodes, FEATS))).astype(np.float32)
    sg_tab = (mu_tab * 0.05).astype(np.float32)
    z = rng.standard_normal(b).astype(np.float32)
    got = np.asarray(
        model.dgemm_model(
            jnp.array(mnk), jnp.array(idx), jnp.array(mu_tab),
            jnp.array(sg_tab), jnp.array(z),
        )
    )
    want = np.asarray(
        ref.ref_durations(
            jnp.array(mnk), jnp.array(mu_tab[idx]), jnp.array(sg_tab[idx]),
            jnp.array(z),
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_solve_spd_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((3, FEATS, FEATS)).astype(np.float32)
    a = a @ np.swapaxes(a, 1, 2) + 0.5 * np.eye(FEATS, dtype=np.float32)
    b = rng.standard_normal((3, FEATS)).astype(np.float32)
    got = np.asarray(model.solve_spd(jnp.array(a), jnp.array(b)))
    want = np.linalg.solve(
        a.astype(np.float64), b.astype(np.float64)[..., None]
    )[..., 0]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-4)


def _planted_fit(noise, s=512, p=4, seed=42):
    rng = np.random.default_rng(seed)
    mnk = np.zeros((p, s, 4), np.float32)
    mnk[..., 0] = rng.integers(64, 4096, (p, s))
    mnk[..., 1] = rng.integers(64, 4096, (p, s))
    mnk[..., 2] = rng.integers(64, 512, (p, s))
    true_mu = np.zeros((p, FEATS), np.float32)
    true_mu[:, 0] = rng.uniform(0.9e-11, 1.3e-11, p)
    true_mu[:, 3] = rng.uniform(0, 5e-10, p)
    true_mu[:, 4] = rng.uniform(1e-5, 1e-4, p)
    true_sg = (true_mu * noise).astype(np.float32)
    feats = np.asarray(ref.ref_features(jnp.array(mnk.reshape(-1, 4))))
    feats = feats.reshape(p, s, FEATS).astype(np.float64)
    zz = np.abs(rng.standard_normal((p, s)))
    y = (feats @ true_mu[:, :, None].astype(np.float64))[..., 0]
    y = y + zz * (feats @ true_sg[:, :, None].astype(np.float64))[..., 0]
    c_mu, c_sg = model.calibrate_entry(
        jnp.array(mnk), jnp.array(y.astype(np.float32))
    )
    return feats, true_mu, true_sg, np.asarray(c_mu), np.asarray(c_sg)


def test_calibrate_noiseless_recovers_mean_predictions():
    feats, true_mu, _, c_mu, c_sg = _planted_fit(noise=0.0)
    pred = np.einsum("psf,pf->ps", feats, c_mu.astype(np.float64))
    want = np.einsum("psf,pf->ps", feats, true_mu.astype(np.float64))
    # Small ridge bias is visible only at tiny (sub-0.1 ms) durations.
    np.testing.assert_allclose(pred, want, rtol=2e-2, atol=1e-5)
    # Sigma model must be (nearly) zero when there is no noise.
    sig = np.einsum("psf,pf->ps", feats, c_sg.astype(np.float64))
    assert np.abs(sig).max() < 0.05 * want.max()


def test_calibrate_recovers_dominant_coefficient_and_noise_scale():
    feats, true_mu, true_sg, c_mu, c_sg = _planted_fit(noise=0.05)
    # Dominant MNK coefficient of the mean model: within a few percent.
    rel = np.abs(c_mu[:, 0] - true_mu[:, 0]) / true_mu[:, 0]
    assert rel.max() < 0.05, rel
    # Sigma predictions at large design points: right order of magnitude.
    big = feats[..., 0] > np.quantile(feats[..., 0], 0.9)
    sig_pred = np.einsum("psf,pf->ps", feats, c_sg.astype(np.float64))[big]
    sig_true = np.einsum("psf,pf->ps", feats, true_sg.astype(np.float64))[big]
    ratio = sig_pred / sig_true
    assert 0.5 < np.median(ratio) < 1.5, np.median(ratio)


def test_calibrate_mean_predictions_unbiased_under_noise():
    feats, true_mu, true_sg, c_mu, _ = _planted_fit(noise=0.05)
    pred = np.einsum("psf,pf->ps", feats, c_mu.astype(np.float64))
    want = np.einsum("psf,pf->ps", feats, true_mu.astype(np.float64))
    big = want > np.quantile(want, 0.5)
    rel = np.abs(pred[big] - want[big]) / want[big]
    assert np.median(rel) < 0.05, np.median(rel)
