"""Layer-2 JAX model: the paper's statistical performance models, batched.

Two entry points, both lowered AOT to HLO text by `aot.py` and executed
from the Rust coordinator via PJRT (never imported at runtime):

* `dgemm_model` — Eq. (1)/(2): given a batch of (M, N, K) triples, per-node
  coefficient tables and standard-normal draws, produce stochastic
  durations.  The hot loop lives in the Pallas kernel
  `kernels.poly_model`.

* `calibrate` — step (1) of the paper's Fig. 2 workflow: per-node OLS fit
  of the 5-term polynomial mean model *and* of the half-normal sigma model
  from benchmark observations.  The Gram accumulation lives in the Pallas
  kernel `kernels.gram`; the 8x8 normal-equation solve is an unrolled
  Cholesky (plain HLO arithmetic — no LAPACK custom-calls, which the
  xla_extension 0.5.1 runtime used by the Rust side may not provide).

Fitting maths.  Observations follow  y = <f, c_mu> + |z| * <f, c_sg>  with
z ~ N(0,1), so  E[y|f] = <f, c_mu + sqrt(2/pi) * c_sg>.  A first fit on y
estimates  c_tot = c_mu + sqrt(2/pi) * c_sg.  Kernel durations are
heteroscedastic (noise scales with size) and the simulator needs good
*relative* accuracy across four decades of shapes, so this fit is a
relative WLS (weights 1/y_i^2), solved on per-column scaled features for
f32 conditioning.  The sigma model is proportional -- sigma = c * mu per
node, matching the paper's observation that temporal variability is a
roughly constant coefficient of variation (~3%, its section 5.2): with
residual  r = y - <f, c_tot>  and  E[|r| | f] = C_ABS * sigma(f),
c = sum(|r| * pred) / (C_ABS * sum(pred^2))  recovers the CV robustly;
then  c_sg = c * c_tot / (1 + c * sqrt(2/pi))  and
c_mu = c_tot - sqrt(2/pi) * c_sg.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import FEATS, gram, poly_model_durations

SQRT_2_OVER_PI = 0.7978845608028654
# E| |z| - sqrt(2/pi) | for z ~ N(0,1); see test_model.py for the
# Monte-Carlo cross-check of this closed-form value.
C_ABS = 0.48262419868598405
# Ridge added to the (standardized) normal equations; also what zeroes the
# padded/degenerate feature lanes.
RIDGE = 1e-5


def dgemm_model(mnk, idx, mu_tab, sg_tab, z):
    """Stochastic durations for a batch of kernel invocations.

    Args:
      mnk:    f32[B, 4]        — (M, N, K, pad) per invocation.
      idx:    i32[B]           — node index per invocation.
      mu_tab: f32[NODES, FEATS] — per-node mean-model coefficients.
      sg_tab: f32[NODES, FEATS] — per-node sigma-model coefficients.
      z:      f32[B]           — standard-normal draws.

    Returns:
      f32[B] durations in seconds.
    """
    mu = jnp.take(mu_tab, idx, axis=0)
    sg = jnp.take(sg_tab, idx, axis=0)
    # One grid step per AOT batch: under interpret=True every grid step
    # costs O(B) in buffer traffic (the Mosaic path would re-tile to
    # BLOCK_B x 8 VMEM blocks instead) — measured 45 M samples/s vs
    # 0.9 M samples/s for 64 steps. See EXPERIMENTS.md §Perf.
    return poly_model_durations(mnk, mu, sg, z, block_b=mnk.shape[0])


def _features(mnk):
    """[..., 4] -> [..., FEATS] polynomial feature expansion."""
    m, n, k = mnk[..., 0], mnk[..., 1], mnk[..., 2]
    one = jnp.ones_like(m)
    zero = jnp.zeros_like(m)
    return jnp.stack(
        [m * n * k, m * n, m * k, n * k, one, zero, zero, zero], axis=-1
    )


def solve_spd(a, b):
    """Unrolled Cholesky solve of an SPD FEATS x FEATS system.

    Pure jnp arithmetic (lowers to plain HLO).  Batched over leading dims.
    a: f32[..., FEATS, FEATS], b: f32[..., FEATS] -> f32[..., FEATS].
    """
    n = FEATS
    # Cholesky: a = L L^T, unrolled at trace time.
    l = [[None] * n for _ in range(n)]
    for i in range(n):
        for j in range(i + 1):
            s = a[..., i, j]
            for k in range(j):
                s = s - l[i][k] * l[j][k]
            if i == j:
                l[i][j] = jnp.sqrt(jnp.maximum(s, 1e-30))
            else:
                l[i][j] = s / l[j][j]
    # Forward substitution L w = b.
    w = [None] * n
    for i in range(n):
        s = b[..., i]
        for k in range(i):
            s = s - l[i][k] * w[k]
        w[i] = s / l[i][i]
    # Back substitution L^T x = w.
    x = [None] * n
    for i in reversed(range(n)):
        s = w[i]
        for k in range(i + 1, n):
            s = s - l[k][i] * x[k]
        x[i] = s / l[i][i]
    return jnp.stack(x, axis=-1)


def _relative_wls(feats, y):
    """Batched relative WLS: minimize sum_i (1 - <f_i, c> / y_i)^2.

    Equivalent to OLS of 1 on f_i / y_i: exact relative weighting, no
    intercept ambiguity (the constant feature lane carries it).

    feats: f32[P, S, FEATS], y: f32[P, S] (strictly positive) ->
    coefficients f32[P, FEATS] in the original feature space.
    """
    s = feats.shape[1]
    yw = jnp.maximum(y, 1e-12)[..., None]
    fw = feats / yw  # [P, S, F]
    # Per-column RMS scaling (no centering) for f32 conditioning.
    scale = jnp.sqrt(jnp.mean(fw * fw, axis=1, keepdims=True))
    scale = jnp.where(scale < 1e-12, 1.0, scale)
    fs = fw / scale
    ones = jnp.ones(y.shape, dtype=feats.dtype)
    g, v = gram(fs, ones)
    g = g + RIDGE * s * jnp.eye(FEATS, dtype=feats.dtype)
    w = solve_spd(g, v)  # [P, F] in scaled space
    return w / scale[:, 0, :]


def calibrate(mnk, y):
    """Per-node fit of the stochastic polynomial model.

    Args:
      mnk: f32[P, S, 4] -- benchmark design points per node.
      y:   f32[P, S]    -- observed durations.

    Returns:
      (mu_coef, sg_coef): f32[P, FEATS] each, such that durations are
      modeled as  <f, mu_coef> + |z| * <f, sg_coef>.
    """
    feats = _features(mnk)
    c_tot = _relative_wls(feats, y)  # mu + sqrt(2/pi) sigma
    pred = jnp.einsum("psf,pf->ps", feats, c_tot)
    resid = y - pred
    # Proportional sigma: project |resid| onto the prediction.
    num = jnp.sum(jnp.abs(resid) * pred, axis=1)
    den = jnp.maximum(C_ABS * jnp.sum(pred * pred, axis=1), 1e-30)
    c = jnp.maximum(num / den, 0.0)  # per-node CV estimate
    sg_scale = c / (1.0 + SQRT_2_OVER_PI * c)
    c_sg = sg_scale[:, None] * c_tot
    c_mu = c_tot - SQRT_2_OVER_PI * c_sg
    return c_mu, c_sg


# ----------------------------------------------------------------------
# Jitted, fixed-shape entry points used by aot.py.
# ----------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=())
def dgemm_model_entry(mnk, idx, mu_tab, sg_tab, z):
    return (dgemm_model(mnk, idx, mu_tab, sg_tab, z),)


@functools.partial(jax.jit, static_argnums=())
def calibrate_entry(mnk, y):
    return calibrate(mnk, y)
