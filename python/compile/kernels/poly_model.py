"""Layer-1 Pallas kernel: stochastic polynomial dgemm-duration model.

Implements Eq. (1)/(2) of Cornebize & Legrand 2021 in batched form:

    dur_i = max(0, mu_i + |z_i| * max(0, sigma_i))
    mu_i    = <feats(M_i, N_i, K_i), mu_coef_i>
    sigma_i = <feats(M_i, N_i, K_i), sg_coef_i>
    feats(M, N, K) = [M*N*K, M*N, M*K, N*K, 1, 0, 0, 0]   (padded to 8 lanes)

The half-normal draw |z|*sigma uses a standard-normal `z` supplied by the
caller (the Rust coordinator owns the RNG so that simulations are
reproducible across layers).

TPU shaping notes (§Hardware-Adaptation in DESIGN.md): the kernel is
elementwise over the batch — one HBM->VMEM stream per block of
`BLOCK_B` samples, feature axis padded to 8 lanes so the layout is
(8, 128)-tileable.  No MXU use; this is a VPU kernel.  `interpret=True`
is mandatory on CPU PJRT (real TPU lowering emits a Mosaic custom-call).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of feature lanes (5 real features, padded to 8 for TPU tiling).
FEATS = 8
# Default batch tile.  8192-sample batches split into 8 grid steps.
BLOCK_B = 1024


def _features(mnk):
    """Feature expansion [b, 4] (M, N, K, pad) -> [b, FEATS].

    Real features: [M*N*K, M*N, M*K, N*K, 1]; lanes 5..7 are zero.
    """
    m = mnk[:, 0]
    n = mnk[:, 1]
    k = mnk[:, 2]
    one = jnp.ones_like(m)
    zero = jnp.zeros_like(m)
    return jnp.stack(
        [m * n * k, m * n, m * k, n * k, one, zero, zero, zero], axis=-1
    )


def _poly_model_kernel(mnk_ref, mu_ref, sg_ref, z_ref, out_ref):
    """One grid step: BLOCK_B samples."""
    feats = _features(mnk_ref[...])
    mu = jnp.sum(feats * mu_ref[...], axis=-1)
    sigma = jnp.maximum(jnp.sum(feats * sg_ref[...], axis=-1), 0.0)
    dur = mu + jnp.abs(z_ref[...]) * sigma
    out_ref[...] = jnp.maximum(dur, 0.0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def poly_model_durations(mnk, mu_coef, sg_coef, z, *, block_b=BLOCK_B):
    """Batched stochastic polynomial durations.

    Args:
      mnk:     f32[B, 4]     — (M, N, K, pad) per sample.
      mu_coef: f32[B, FEATS] — per-sample mean-model coefficients.
      sg_coef: f32[B, FEATS] — per-sample sigma-model coefficients.
      z:       f32[B]        — standard-normal draws.
      block_b: batch tile size (must divide B).

    Returns:
      f32[B] durations (seconds), non-negative.
    """
    b = mnk.shape[0]
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    return pl.pallas_call(
        _poly_model_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 4), lambda i: (i, 0)),
            pl.BlockSpec((block_b, FEATS), lambda i: (i, 0)),
            pl.BlockSpec((block_b, FEATS), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(mnk, mu_coef, sg_coef, z)
