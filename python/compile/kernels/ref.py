"""Pure-jnp oracles for the Pallas kernels (correctness references).

Everything here is straight-line jnp with no Pallas: pytest compares the
kernels against these, and the Rust integration tests compare the loaded
PJRT artifacts against values exported from these.
"""

from __future__ import annotations

import jax.numpy as jnp

from .poly_model import FEATS


def ref_features(mnk):
    """[B, 4] (M, N, K, pad) -> [B, FEATS] feature expansion."""
    m, n, k = mnk[:, 0], mnk[:, 1], mnk[:, 2]
    one = jnp.ones_like(m)
    zero = jnp.zeros_like(m)
    return jnp.stack(
        [m * n * k, m * n, m * k, n * k, one, zero, zero, zero], axis=-1
    )


def ref_durations(mnk, mu_coef, sg_coef, z):
    """Oracle for poly_model.poly_model_durations."""
    feats = ref_features(mnk)
    mu = jnp.sum(feats * mu_coef, axis=-1)
    sigma = jnp.maximum(jnp.sum(feats * sg_coef, axis=-1), 0.0)
    return jnp.maximum(mu + jnp.abs(z) * sigma, 0.0)


def ref_gram(feats, y):
    """Oracle for gram.gram: einsum normal-equation blocks."""
    g = jnp.einsum("psf,psg->pfg", feats, feats)
    v = jnp.einsum("psf,ps->pf", feats, y)
    return g, v


def ref_ols(feats, y, ridge=1e-6):
    """Reference batched OLS fit via jnp.linalg.solve (test-only)."""
    g, v = ref_gram(feats, y)
    eye = jnp.eye(FEATS, dtype=feats.dtype)
    return jnp.linalg.solve(g + ridge * eye, v[..., None])[..., 0]
