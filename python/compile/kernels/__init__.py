"""Layer-1 Pallas kernels for hplsim (build-time only, never at runtime)."""

from .poly_model import FEATS, poly_model_durations
from .gram import gram

__all__ = ["FEATS", "poly_model_durations", "gram"]
