"""Layer-1 Pallas kernel: per-node Gram accumulation for OLS calibration.

For each node p with design matrix F_p (S samples x FEATS features) and
observations y_p (S), computes the normal-equation blocks

    G_p = F_p^T F_p          (FEATS x FEATS)
    v_p = F_p^T y_p          (FEATS)

which Layer-2 then solves with an unrolled Cholesky (`model.solve_spd`).

TPU shaping (§Hardware-Adaptation): this is the MXU-shaped piece — an
(S x F)^T @ (S x F) reduction.  The grid iterates over (node, sample-block);
each step does a (F x BLOCK_S) @ (BLOCK_S x F) matmul into a persistent
f32 VMEM accumulator (FEATS=8 -> G tile is 8x8, v is 8; ~0.3 KB of
accumulator state).  `interpret=True` for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .poly_model import FEATS

# Sample-axis tile.
BLOCK_S = 256


def _gram_kernel(f_ref, y_ref, g_ref, v_ref):
    """Grid step (p, s): accumulate one sample block of node p."""
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        v_ref[...] = jnp.zeros_like(v_ref)

    f = f_ref[0]  # [BLOCK_S, FEATS]
    y = y_ref[0]  # [BLOCK_S]
    g_ref[0] += jnp.dot(f.T, f, preferred_element_type=jnp.float32)
    v_ref[0] += jnp.dot(f.T, y[:, None], preferred_element_type=jnp.float32)[
        :, 0
    ]


@functools.partial(jax.jit, static_argnames=("block_s",))
def gram(feats, y, *, block_s=BLOCK_S):
    """Per-node Gram blocks.

    Args:
      feats: f32[P, S, FEATS] — per-node design matrices.
      y:     f32[P, S]        — per-node observations.
      block_s: sample tile (must divide S).

    Returns:
      (g, v): f32[P, FEATS, FEATS], f32[P, FEATS].
    """
    p, s, f = feats.shape
    assert f == FEATS, feats.shape
    assert s % block_s == 0, (s, block_s)
    grid = (p, s // block_s)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, FEATS), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, FEATS, FEATS), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, FEATS), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, FEATS, FEATS), jnp.float32),
            jax.ShapeDtypeStruct((p, FEATS), jnp.float32),
        ],
        interpret=True,
    )(feats, y)
