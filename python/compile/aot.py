"""AOT compile path: lower the Layer-2 model to HLO *text* artifacts.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla_extension 0.5.1
runtime behind the Rust `xla` crate rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Artifacts (fixed shapes; the Rust runtime chunks + pads):

  dgemm_model_<B>.hlo.txt   (mnk f32[B,4], idx i32[B], mu f32[NODES,8],
                             sg f32[NODES,8], z f32[B]) -> (dur f32[B],)
  calibrate.hlo.txt         (mnk f32[P,S,4], y f32[P,S])
                             -> (mu_coef f32[P,8], sg_coef f32[P,8])

A `manifest.json` records every artifact's shapes so the Rust side can
sanity-check at load time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import FEATS

# Fixed AOT shapes.
NODES = 1024  # max nodes addressable by one coefficient table
BATCHES = (512, 8192, 65536)  # dgemm_model variants (small/med/large)
CAL_P = 32  # nodes per calibration chunk
CAL_S = 512  # benchmark samples per node


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_dgemm_model(batch: int):
    args = (
        _spec((batch, 4)),
        _spec((batch,), jnp.int32),
        _spec((NODES, FEATS)),
        _spec((NODES, FEATS)),
        _spec((batch,)),
    )
    return jax.jit(model.dgemm_model_entry).lower(*args), args


def lower_calibrate():
    args = (_spec((CAL_P, CAL_S, 4)), _spec((CAL_P, CAL_S)))
    return jax.jit(model.calibrate_entry).lower(*args), args


def _manifest_entry(args, outs):
    def fmt(s):
        return {"shape": list(s.shape), "dtype": str(s.dtype)}

    return {"inputs": [fmt(a) for a in args], "outputs": [fmt(o) for o in outs]}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"feats": FEATS, "nodes": NODES, "cal_p": CAL_P, "cal_s": CAL_S}

    for batch in BATCHES:
        lowered, specs = lower_dgemm_model(batch)
        text = to_hlo_text(lowered)
        name = f"dgemm_model_{batch}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(model.dgemm_model_entry, *specs)
        manifest[name] = _manifest_entry(specs, outs)
        print(f"wrote {path} ({len(text)} chars)")

    lowered, specs = lower_calibrate()
    text = to_hlo_text(lowered)
    path = os.path.join(args.out_dir, "calibrate.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(model.calibrate_entry, *specs)
    manifest["calibrate"] = _manifest_entry(specs, outs)
    print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
